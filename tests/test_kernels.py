"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.
All kernels run in interpret mode (CPU container; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.adaptive_update import adaptive_update_slab
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ota_channel import ota_channel_slab
from repro.kernels.ref import (adaptive_update_ref, flash_attention_ref,
                               ota_channel_ref)

HP = dict(lr=0.02, beta1=0.9, beta2=0.3, alpha=1.5, eps=1e-8)


@pytest.mark.parametrize("n", [1, 127, 128, 1000, 4096, 70_000])
@pytest.mark.parametrize("mode", ["adagrad", "adam"])
@pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
def test_adaptive_update_sweep(n, mode, wdtype):
    ks = jax.random.split(jax.random.key(n), 4)
    g = jax.random.normal(ks[0], (n,), wdtype)
    d0 = jax.random.normal(ks[1], (n,), jnp.float32)
    v0 = jnp.abs(jax.random.normal(ks[2], (n,), jnp.float32))
    w0 = jax.random.normal(ks[3], (n,), wdtype)
    dn, vn, wn = adaptive_update_slab(g, d0, v0, w0, mode=mode, **HP)
    dr, vr, wr = adaptive_update_ref(g, d0, v0, w0, mode=mode, **HP)
    np.testing.assert_allclose(np.asarray(dn), np.asarray(dr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(vn), np.asarray(vr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(wn, np.float32),
                               np.asarray(wr, np.float32),
                               rtol=2e-2 if wdtype == jnp.bfloat16 else 2e-5,
                               atol=2e-2 if wdtype == jnp.bfloat16 else 2e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 2000), alpha=st.floats(1.05, 2.0),
       beta1=st.floats(0.0, 0.99))
def test_adaptive_update_property(n, alpha, beta1):
    hp = dict(lr=0.02, beta1=beta1, beta2=0.3, alpha=alpha, eps=1e-8)
    ks = jax.random.split(jax.random.key(n), 4)
    g = jax.random.normal(ks[0], (n,))
    d0 = jax.random.normal(ks[1], (n,))
    v0 = jnp.abs(jax.random.normal(ks[2], (n,)))
    w0 = jax.random.normal(ks[3], (n,))
    dn, vn, wn = adaptive_update_slab(g, d0, v0, w0, mode="adam", **hp)
    dr, vr, wr = adaptive_update_ref(g, d0, v0, w0, mode="adam", **hp)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(wr),
                               rtol=5e-5, atol=5e-5)
    # nu stays nonneg (stepsize denominator well-defined)
    assert float(jnp.min(vn)) >= 0.0


@pytest.mark.parametrize("n_clients,d", [(1, 100), (8, 513), (50, 2048)])
@pytest.mark.parametrize("alpha", [1.2, 1.7, 2.0])
def test_ota_channel_sweep(n_clients, d, alpha):
    ks = jax.random.split(jax.random.key(d + n_clients), 4)
    G = jax.random.normal(ks[0], (n_clients, d))
    h = jax.random.uniform(ks[1], (n_clients,), minval=0.1, maxval=2.0)
    u = jax.random.uniform(ks[2], (d,), minval=-1.57, maxval=1.57)
    e = -jnp.log(jax.random.uniform(ks[3], (d,), minval=1e-6))
    out = ota_channel_slab(G, h, u, e, alpha=alpha, scale=0.1)
    ref = ota_channel_ref(G, h, u, e, alpha=alpha, scale=0.1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("mode", ["amsgrad", "yogi", "momentum", "sgd"])
@pytest.mark.parametrize("n", [1, 127, 1000, 70_000])
def test_adaptive_update_extended_modes(mode, n):
    """The new fused modes match the jnp oracle on the same slab."""
    ks = jax.random.split(jax.random.key(n), 5)
    g = jax.random.normal(ks[0], (n,))
    d0 = jax.random.normal(ks[1], (n,))
    v0 = jnp.abs(jax.random.normal(ks[2], (n,)))
    m0 = v0 + jnp.abs(jax.random.normal(ks[3], (n,)))
    w0 = jax.random.normal(ks[4], (n,))
    kw = dict(mode=mode, nu_max=(m0 if mode == "amsgrad" else None), **HP)
    outs = adaptive_update_slab(g, d0, v0, w0, **kw)
    refs = adaptive_update_ref(g, d0, v0, w0, **kw)
    assert len(outs) == len(refs) == {"amsgrad": 4, "yogi": 3,
                                      "momentum": 2, "sgd": 1}[mode]
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


def test_adaptive_update_unknown_mode_rejected():
    z = jnp.zeros(8)
    with pytest.raises(ValueError):
        adaptive_update_slab(z, z, z, z, mode="rmsprop", **HP)


def test_ota_channel_alpha_guards():
    """Satellite: the CMS kernel matches core.channel's guards — tail
    index validated to (1, 2], endpoint angles finite, alpha == 2 reduces
    to the Gaussian special case 2*sin(u)*sqrt(e)."""
    import math
    G = jnp.zeros((2, 8))
    h = jnp.ones(2)
    # endpoint angles included: f32 cos(pi/2) is slightly NEGATIVE, which
    # made the unguarded transform NaN for every alpha.
    u = jnp.array([math.pi / 2, -math.pi / 2, 0.0, 1.0, -1.0, 1.5, -1.5,
                   0.5], jnp.float32)
    e = jnp.abs(jax.random.normal(jax.random.key(0), (8,))) + 0.1

    for bad in (1.0, 0.5, 2.5, -1.5):
        with pytest.raises(ValueError):
            ota_channel_slab(G, h, u, e, alpha=bad, scale=0.1)

    for alpha in (1.05, 1.5, 2.0):
        out = ota_channel_slab(G, h, u, e, alpha=alpha, scale=1.0)
        assert bool(jnp.all(jnp.isfinite(out))), alpha
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(ota_channel_ref(G, h, u, e, alpha=alpha, scale=1.0)),
            rtol=3e-4, atol=3e-4)

    # alpha == 2: Gaussian reduction (away from the clipped endpoints).
    out2 = ota_channel_slab(G, h, u, e, alpha=2.0, scale=1.0)
    gauss = 2.0 * jnp.sin(u) * jnp.sqrt(e)
    np.testing.assert_allclose(np.asarray(out2[2:]), np.asarray(gauss[2:]),
                               rtol=1e-4, atol=1e-4)


def test_ota_channel_matches_sampler_draws():
    """Feeding the kernel the sampler's own (u, e) draws reproduces
    sample_alpha_stable exactly — the identity the pallas channel backend
    relies on for bit-parity with the jnp backend."""
    from repro.core.channel import cms_inputs, sample_alpha_stable
    key = jax.random.key(123)
    d = 3000
    u, e = cms_inputs(key, (d,))
    for alpha in (1.2, 1.7, 2.0):
        xi_ref = sample_alpha_stable(key, alpha, (d,), scale=0.3)
        out = ota_channel_slab(jnp.zeros((1, d)), jnp.zeros(1), u, e,
                               alpha=alpha, scale=0.3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(xi_ref),
                                   rtol=2e-5, atol=2e-6)


FLASH_CASES = [
    # (B, Sq, Sk, H, K, D, causal, window, bq, bk)
    (1, 32, 32, 2, 2, 16, True, None, 16, 16),
    (2, 64, 64, 4, 2, 32, True, None, 32, 32),
    (1, 100, 100, 8, 8, 64, True, 48, 32, 32),
    (2, 1, 96, 4, 2, 32, False, None, 8, 32),     # decode-like
    (1, 80, 80, 6, 3, 16, True, 16, 16, 16),      # GQA group 2 + window
    (1, 33, 65, 2, 1, 8, False, None, 16, 16),    # ragged padding
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_sweep(case):
    b, sq, sk, h, kh, d, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.key(sum(case[:6])), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kh, d), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=bq, bk=bk)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(jax.random.key(0), (1, 64, 4, 32), dtype)
    k = jax.random.normal(jax.random.key(1), (1, 64, 2, 32), dtype)
    v = jax.random.normal(jax.random.key(2), (1, 64, 2, 32), dtype)
    out = flash_attention(q, k, v, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_fused_server_update_equals_optimizer():
    """ops.fused_server_update == core adam_ota.update on a pytree."""
    from repro.core.adaptive import AdaptiveConfig, adam_ota
    from repro.kernels.ops import fused_server_update
    params = {"a": jnp.ones((130,)), "b": {"c": jnp.ones((5, 60), jnp.bfloat16)}}
    g = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    cfg = AdaptiveConfig(optimizer="adam_ota", lr=0.01, beta2=0.3, alpha=1.5)
    opt = adam_ota(cfg)
    st0 = opt.init(params)
    ref_p, ref_s = opt.update(g, st0, params)
    k_p, k_s = fused_server_update(g, st0, params, lr=0.01, beta1=0.9,
                                   beta2=0.3, alpha=1.5, eps=1e-8, mode="adam")
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(k_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(ref_s.nu)[0]),
        np.asarray(jax.tree.leaves(k_s.nu)[0]), rtol=1e-5)


@pytest.mark.parametrize("name,mode", [("adagrad_ota", "adagrad"),
                                       ("amsgrad_ota", "amsgrad"),
                                       ("yogi_ota", "yogi"),
                                       ("fedavgm", "momentum"),
                                       ("fedavg", "sgd")])
def test_fused_server_update_all_modes(name, mode):
    """ops.fused_server_update handles every mode the kernel advertises
    (regression: it used to crash on amsgrad/momentum/sgd state)."""
    from repro.core.adaptive import AdaptiveConfig, make_server_optimizer
    from repro.kernels.ops import fused_server_update
    params = {"a": jnp.ones((130,)), "b": {"c": jnp.full((5, 60), 0.5)}}
    g = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    cfg = AdaptiveConfig(optimizer=name, lr=0.01, beta2=0.3, alpha=1.5)
    opt = make_server_optimizer(cfg)
    st0 = opt.init(params)
    ref_p, ref_s = opt.update(g, st0, params)
    beta1 = cfg.momentum if mode == "momentum" else cfg.beta1
    k_p, k_s = fused_server_update(g, st0, params, lr=0.01, beta1=beta1,
                                   beta2=0.3, alpha=1.5, eps=1e-8, mode=mode)
    for a, b in zip(jax.tree.leaves(ref_p), jax.tree.leaves(k_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(ref_s.nu), jax.tree.leaves(k_s.nu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        fused_server_update(g, st0, params, lr=0.01, beta1=0.9, beta2=0.3,
                            alpha=1.5, eps=1e-8, mode="rmsprop")


# ---------------------------------------------------------------------------
# Interpret-mode grid coarsening (PR 8)
# ---------------------------------------------------------------------------

def test_coarse_block_policy():
    """coarse_block only ever grows the tile under interpret, in whole
    multiples of the requested block, capped, and never past the padded
    axis."""
    from repro.kernels.interpret import INTERPRET_BLOCK_CAP, coarse_block
    # compiled mode: untouched, whatever the size
    assert coarse_block(1 << 20, 256, False) == 256
    # already a single tile: untouched
    assert coarse_block(100, 256, True) == 256
    # grows to the whole padded axis...
    assert coarse_block(1000, 256, True) == 1024
    # ...capped (in multiples of block), for huge axes
    big = coarse_block(1 << 22, 256, True)
    assert big == (INTERPRET_BLOCK_CAP // 256) * 256
    assert big % 256 == 0
    # a custom cap below the axis still yields a block multiple
    assert coarse_block(10_000, 256, True, cap=1000) == 768


def test_coarse_block_bitwise_invariant():
    """The coarsened interpret launch is BITWISE identical to the
    fixed-tile launch on the channel output — per-column math and the
    per-128-block scales are invariant to the d-axis tiling (the
    assertion coarse_block's docstring promises). The pilot-stats
    scalars reduce ACROSS tiles, so their accumulation order follows
    the grid: those are held to ~1 ULP instead."""
    import repro.kernels.ota_channel as oc

    n, d = 4, 1000   # 4 x 256-tiles when fixed, 1 tile when coarsened
    ks = jax.random.split(jax.random.key(42), 4)
    g = jax.random.normal(ks[0], (n, d))
    h = jnp.abs(jax.random.normal(ks[1], (n,))) + 0.1
    u = jax.random.uniform(ks[2], (d,), minval=-1.5, maxval=1.5)
    e = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.01

    def launch():
        return oc.ota_channel_slab(g, h, u, e, alpha=1.5, scale=0.1,
                                   pilot_stats=True, block_cols=256,
                                   interpret=True)

    coarse_out, coarse_stats = launch()
    orig = oc.coarse_block
    oc.coarse_block = lambda n_, b, i, cap=None: b   # fixed-tile baseline
    try:
        fixed_out, fixed_stats = launch()
    finally:
        oc.coarse_block = orig
    np.testing.assert_array_equal(np.asarray(coarse_out),
                                  np.asarray(fixed_out))
    for a, b in zip(coarse_stats, fixed_stats):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=0)
