"""Pallas TPU kernels for the ADOTA-FL hot spots.

adaptive_update -- fused Delta/v/w server update (one HBM pass)
ota_channel     -- fused fading-reduction + CMS alpha-stable interference
flash_attention -- blocked causal/sliding-window GQA attention

Each has a pure-jnp oracle in ref.py and a jit wrapper in ops.py.
Kernels target TPU (BlockSpec VMEM tiling); on CPU they run via
interpret=True (tests) -- the model/dry-run paths use the jnp refs.
"""

from repro.kernels.adaptive_update import adaptive_update_slab
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ota_channel import ota_channel_slab

__all__ = ["adaptive_update_slab", "flash_attention", "ota_channel_slab"]
