"""OTA uplink Pallas kernels: the MAC as a staged transmit/receive pair.

The uplink pipeline (paper Eq. 7, restructured in PR 4) is

    transmit power control -> quantize -> MAC superposition
        -> interference injection -> receiver dequantize/scale

and this module owns the kernel stages of it:

* ``ota_transmit_slab`` — the transmitter: the fading-scaled partial
  reduction ``(1/N) sum_n h[n] * G[n, :]`` over this transmitter's
  stacked client gradients (power control is upstream, folded into the
  effective ``h``). With ``quantize=True`` the kernel runs a fused
  *quantize-on-write epilogue*: each (1, LANE) group of the partial sum
  gets one f32 scale (symmetric, max|x|/127) and is written as int8
  with stochastic rounding (``floor(x/s + r)``, r ~ U[0,1) produced
  upstream so all backends make identical rounding decisions) — the
  payload leaves the kernel already in wire format, one read of G.

* ``ota_receive_slab`` — the server's RF front end: dequantizes R
  payload rows (R transmitters after the collective; R == 1 single-
  device), sums them, and injects the Chambers-Mallows-Stuck
  alpha-stable interference in the same VMEM tile.

* ``ota_channel_slab`` — the original single-launch fused f32 channel
  (faded reduction + CMS interference, one pass); still the f32 fast
  path: splitting it would buy nothing when there is no wire format to
  stage around, and keeping it guarantees the ``uplink="f32"`` round is
  bitwise-identical to the pre-pipeline code.

**Pilot-statistics epilogue** (``pilot_stats=True`` on the two
interference-injecting launches, PR 5): the online tail-index tracker
(paper Remark 3, ``repro.core.tail_index``) needs log-moment statistics
of the interference residual r = xi_scale * xi — which these kernels
hold in-register anyway. Rather than re-synthesizing the residual in a
second pass, each grid step reduces its tile to
``[count, sum log|r|, sum log^2|r|]`` over the NONZERO residual entries
(the padding tail synthesizes exactly 0 and drops out; a disabled
channel reduces to count == 0) and writes them into its own row of a
tiny (grid, LANE) side output; the caller sums the rows. Per-step rows
instead of cross-step accumulation keep the epilogue trivially correct
under any grid execution order. The stats are subset-agnostic — a shard
slice's 3-vector simply psum-adds with its peers' — which is what lets
the sharded engine reduce them like the RoundMetrics norms. The main
output is untouched, and with ``pilot_stats=False`` (the default) the
launch is the exact pre-PR-5 ``pallas_call`` — the static-alpha path
stays bitwise.

The CMS math is ``repro.core.channel.cms_transform`` — the same guarded
expression the jnp sampler uses, so kernel and reference agree bitwise
in interpret mode: angles are clipped strictly inside (-pi/2, pi/2)
(endpoint angles made the old log-space form NaN, even at alpha == 2
where the transform reduces to the finite Gaussian 2*sin(u)*sqrt(e))
and the Exp(1) draws are floored. The tail index is validated against
the same (1, 2] range as ``OTAChannelConfig``.

Grid: 1-D over column blocks of size (N, block_cols); the N reduction
runs inside the tile (N = clients-per-shard is small, <= a few hundred).
``interpret=None`` auto-selects Pallas interpret mode from the platform
(``repro.kernels.interpret``): compiled on TPU, interpreted elsewhere.

**Streamed client axis** (PR 6): with ``acc=`` and/or ``row_chunk=`` the
transmit grid gains a CLIENT-CHUNK dimension — ``(col_blocks,
row_chunks)``, column blocks outer so each output tile is revisited
consecutively — and the kernel accumulates the faded partial sum
in-place across the row chunks (``@pl.when(r == 0)`` seeds the output
tile from the ``acc`` carry, every step adds its chunk's
``sum_rows(h*g)/n_total``). ``acc`` chains launches: a round streams N
clients as a ``lax.scan`` over gradient chunks, each chunk's transmit
launch folding into the running (d,) partial — peak memory is
O(chunk * d) regardless of N, and ``n_total`` keeps the 1/N wire
normalisation identical to the resident launch. With one row chunk the
accumulation is ``0 + sum(h*g)/n_total`` — bitwise-equal to the
resident kernel — so streaming with ``chunk >= N`` is a pure memory
optimization (the parity guard in tests/test_stream.py pins this).
Quantization composes by accumulating the f32 partial first and
quantizing the COMPLETED sum through a single-row ``quantize=True``
launch (one quantization step per entry, the wire contract).

**Wire-format matrix** (PR 7): the quantize epilogue speaks two wire
formats — ``qmode="int8"`` (symmetric max|x|/127 + stochastic rounding)
and ``qmode="sign"`` (1-bit signSGD: payload = sign(x) in {-1, 0, +1}
on the same int8 wire container, one f32 mean|x| magnitude per
128-block, deterministic). Both dequantize through the unchanged
``ota_receive_slab`` (payload * per-block scale). Per-transmitter error
feedback composes in the same launch: the carried residual ``ef`` joins
the faded partial before quantization and ``return_residual=True``
writes the fresh residual ``x - dequant(quant(x))`` as a third output —
the EF loop costs one extra (1, bc) read + write per tile, never a
second pass over G.

Sharded slab engine: when the round is distributed over a device mesh
(``repro.core.shard``), each device launches the transmit kernel on its
LOCAL client shard only, passing ``n_total`` = the global client count
so the 1/N normalisation matches the single-device launch; the
cross-device collective then completes the superposition (the mesh is
the multiple-access channel) and the receive kernel runs on each
device's slab slice. The grid covers just the local rows/columns, so
the launch cost scales down with the shard, not the model.

**Compiled-mode fast path** (PR 8) — two compiled-only refinements
close the gap between the byte model and what actually moves:

* *In-kernel stochastic rounding* (``sr_seed=`` on the quantized
  transmit): the epilogue draws its rounding bits inside the kernel —
  ``pltpu.prng_seed(seed, program_id)`` + ``pltpu.prng_random_bits``,
  the seed derived from the round key by
  ``repro.core.channel.sr_kernel_seed`` (the same fold chain as the
  host-drawn uniforms) — instead of streaming the (1, d) f32 host
  draws through HBM: one less d-word read per transmit. The pltpu PRNG
  only lowers on TPU, so ``sr_seed`` demands a compiled launch
  (interpret raises); the host-drawn path stays the interpret/parity
  oracle, and because the in-kernel bits are a *different* uniform
  stream, agreement with that oracle is the one-quantization-step
  contract documented in ``kernels/ref.py``, not bitwise.

* *Bit-packed sign wire* (``pack_sign_slab`` / ``unpack_sign_slab``,
  ``ota_receive_slab(packed=...)``): the {-1, 0, +1} sign payload
  leaves the transmit WRAPPER packed 32 coords per uint32 word — the
  sign plane alone when the quantizer zero-folds (``zero_fold=True``:
  q in {-1, +1}, all-zero blocks scale 0 — a true 1 bit/coord wire),
  or sign + nonzero planes (2 bits/coord) preserving arbitrary
  {-1, 0, +1} bitwise. The receive prologue unpacks before the fused
  dequantize launch. Packing sits at the XLA level rather than in the
  kernel body deliberately: a (1, block_cols // 32) uint32 output tile
  would violate the lane alignment the compiled epilogue must keep,
  and XLA fuses the word-assembly into the payload's consumer anyway.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.channel import cms_transform
from repro.kernels.interpret import coarse_block, resolve_interpret

LANE = 128
DEFAULT_BLOCK_COLS = 512
INT8_MAX = 127.0


def _residual_stats_row(xi: jax.Array, scale: float) -> jax.Array:
    """The pilot-statistics epilogue, shared by the channel and receive
    kernels: reduce this tile's interference residual ``r = scale * xi``
    to one (1, LANE) row ``[count, sum log|r|, sum log^2|r|, 0, ...]``
    over the nonzero entries (zero-mask == the padding/disabled-channel
    fixed point). Runs on values already in VMEM/VREGs."""
    r = jnp.abs(scale * xi.astype(jnp.float32)).reshape(-1)
    m = r > 0.0
    logr = jnp.where(m, jnp.log(jnp.maximum(r, jnp.finfo(jnp.float32).tiny)),
                     0.0)
    cnt = jnp.sum(m.astype(jnp.float32))
    s1 = jnp.sum(logr)
    s2 = jnp.sum(logr * logr)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
    return jnp.where(lane == 0, cnt,
                     jnp.where(lane == 1, s1,
                               jnp.where(lane == 2, s2, 0.0)))


def _sum_stats_rows(rows: jax.Array) -> jax.Array:
    """(grid, LANE) per-step stats rows -> the (3,) reduced statistics."""
    return jnp.sum(rows, axis=0)[:3]


def _ota_kernel(*refs, alpha: float, scale: float, n_clients: int,
                stats: bool):
    g_ref, h_ref, u_ref, e_ref, out_ref = refs[:5]
    g = g_ref[...].astype(jnp.float32)              # (N, bc)
    h = h_ref[...].astype(jnp.float32)              # (N, 1)
    agg = jnp.sum(h * g, axis=0, keepdims=True) / n_clients   # (1, bc)
    xi = cms_transform(u_ref[...], e_ref[...], alpha)         # (1, bc)
    out_ref[...] = agg + scale * xi
    if stats:
        refs[5][...] = _residual_stats_row(xi, scale)


def ota_channel_slab(grads: jax.Array, h: jax.Array, u: jax.Array,
                     e: jax.Array, *, alpha: float, scale: float,
                     n_total: int | None = None,
                     pilot_stats: bool = False,
                     block_cols: int = DEFAULT_BLOCK_COLS,
                     interpret: Optional[bool] = None):
    """Fused f32 channel: grads (N, d) stacked client gradients, h (N,)
    fading draws, u (d,) uniform angles in (-pi/2, pi/2), e (d,) Exp(1)
    draws. Returns the aggregated noisy gradient (d,) float32.

    ``n_total`` overrides the 1/N normalisation (defaults to the local
    row count N). The sharded engine passes the GLOBAL client count here
    while feeding only this shard's rows, so per-shard partial sums psum
    to exactly the single-device aggregate.

    ``pilot_stats=True`` additionally returns the (3,) log-moment
    statistics of the injected interference residual (the fused
    epilogue; see the module docstring) as ``(out, stats)``."""
    if not (1.0 < alpha <= 2.0):
        raise ValueError(f"tail index alpha must be in (1, 2], got {alpha}")
    interpret = resolve_interpret(interpret)
    n, d = grads.shape
    if n_total is None:
        n_total = n
    block_cols = coarse_block(d, block_cols, interpret)
    d_pad = -(-d // block_cols) * block_cols
    gp = jnp.pad(grads, ((0, 0), (0, d_pad - d)))
    up = jnp.pad(u, (0, d_pad - d)).reshape(1, d_pad)
    ep = jnp.pad(e, (0, d_pad - d), constant_values=1.0).reshape(1, d_pad)
    h2 = h.reshape(n, 1).astype(jnp.float32)

    grid = (d_pad // block_cols,)
    out_specs = pl.BlockSpec((1, block_cols), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((1, d_pad), jnp.float32)
    if pilot_stats:
        out_specs = [out_specs, pl.BlockSpec((1, LANE), lambda i: (i, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((grid[0], LANE), jnp.float32)]
    outs = pl.pallas_call(
        functools.partial(_ota_kernel, alpha=alpha, scale=scale,
                          n_clients=n_total, stats=pilot_stats),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_cols), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(gp, h2, up, ep)
    if pilot_stats:
        return outs[0].reshape(-1)[:d], _sum_stats_rows(outs[1])
    return outs.reshape(-1)[:d]


# ---------------------------------------------------------------------------
# Staged pipeline kernels: transmit (+ quantize epilogue) and receive.
# ---------------------------------------------------------------------------

def _tx_kernel(g_ref, h_ref, out_ref, *, n_clients: int):
    g = g_ref[...].astype(jnp.float32)              # (N, bc)
    h = h_ref[...].astype(jnp.float32)              # (N, 1)
    out_ref[...] = jnp.sum(h * g, axis=0, keepdims=True) / n_clients


def _tx_stream_kernel(g_ref, h_ref, acc_ref, out_ref, *, n_clients: int):
    """Streamed transmit: grid (col_blocks, row_chunks), col-outer. The
    first row chunk seeds this column's output tile from the ``acc``
    carry; every chunk then folds its faded partial in-place."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _seed():
        out_ref[...] = acc_ref[...]

    g = g_ref[...].astype(jnp.float32)              # (rc, bc)
    h = h_ref[...].astype(jnp.float32)              # (rc, 1)
    out_ref[...] = out_ref[...] + jnp.sum(h * g, axis=0,
                                          keepdims=True) / n_clients


def _tx_quant_kernel(*refs, n_clients: int, stochastic: bool, qmode: str,
                     ef: bool, resid: bool, zero_fold: bool,
                     inkernel_sr: bool):
    # refs[2] is the (1, d) host-drawn SR uniforms, EXCEPT under
    # inkernel_sr where the same slot carries the (1, 1) int32 SMEM
    # seed (the host draws are never materialized then).
    if ef:
        g_ref, h_ref, r_ref, ef_ref = refs[:4]
        outs = refs[4:]
    else:
        g_ref, h_ref, r_ref = refs[:3]
        outs = refs[3:]
    q_ref, s_ref = outs[:2]
    g = g_ref[...].astype(jnp.float32)              # (N, bc)
    h = h_ref[...].astype(jnp.float32)              # (N, 1)
    agg = jnp.sum(h * g, axis=0, keepdims=True) / n_clients   # (1, bc)
    if ef:
        # Error feedback: the residual carried from the previous round
        # joins the faded partial BEFORE quantization, so what the wire
        # loses this round is re-offered next round.
        agg = agg + ef_ref[...].astype(jnp.float32)
    bc = agg.shape[1]
    a = agg.reshape(bc // LANE, LANE)
    if qmode == "sign":
        # 1-bit signSGD payload: per-block magnitude = mean|x| (the L1
        # scale that makes +/-s the least-squares sign reconstruction),
        # payload = sign(x) on the int8 wire container. Deterministic
        # (canonical EF-signSGD) — the SR draws are ignored.
        meanabs = jnp.mean(jnp.abs(a), axis=1, keepdims=True)  # (nb, 1)
        if zero_fold:
            # Zero-folding (the 1-bit packable variant): q in {-1, +1}
            # only — exact zeros fold to +1 — and all-zero blocks keep
            # scale 0, so the slab's zero tail still dequantizes to
            # exactly 0 (+1 * 0). An isolated exact zero inside a
            # nonzero block dequantizes to +s: one quantization step,
            # within the documented wire contract, and measure-zero in
            # gradient data.
            s = meanabs
            q = jnp.where(a < 0.0, -1, 1).astype(jnp.int8)
        else:
            # {-1, 0, +1} container variant: all-zero blocks keep scale
            # 1 -> payload 0, the same zero-tail fixed point as int8.
            s = jnp.where(meanabs > 0.0, meanabs, 1.0)
            q = jnp.sign(a).astype(jnp.int8)
    else:
        maxabs = jnp.max(jnp.abs(a), axis=1, keepdims=True)    # (nb, 1)
        # All-zero blocks (the slab's zero tail) keep scale 1 -> payload
        # 0, so quantization preserves the zero-padding contract exactly.
        s = jnp.where(maxabs > 0.0, maxabs / INT8_MAX, 1.0)
        y = a / s
        if stochastic and inkernel_sr:
            # Compiled-mode fast path: draw the rounding uniforms
            # in-kernel. Seeding folds the grid step in so every column
            # block draws a distinct stream; the low 24 bits of each
            # word become a uniform on [0, 1) at float32's native SR
            # granularity (2^-24 = one ulp at 1.0).
            pltpu.prng_seed(r_ref[0, 0], pl.program_id(0))
            bits = pltpu.prng_random_bits(y.shape)
            u24 = jnp.bitwise_and(bits, (1 << 24) - 1)
            y = jnp.floor(y + u24.astype(jnp.float32) * (1.0 / (1 << 24)))
        elif stochastic:
            y = jnp.floor(y + r_ref[...].reshape(bc // LANE, LANE))
        else:
            y = jnp.round(y)
        q = jnp.clip(y, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    q_ref[...] = q.reshape(1, bc)
    s_ref[...] = s.reshape(1, bc // LANE)
    if resid:
        # What the wire will NOT deliver: x - dequant(quant(x)), with x
        # the EF-augmented partial — still in-register, one extra write.
        deq = q.astype(jnp.float32) * s
        outs[2][...] = (a - deq).reshape(1, bc)


def ota_transmit_slab(grads: jax.Array, h: jax.Array, *,
                      n_total: int | None = None, quantize: bool = False,
                      r: Optional[jax.Array] = None, stochastic: bool = True,
                      qmode: str = "int8", zero_fold: bool = False,
                      sr_seed: Optional[jax.Array] = None,
                      ef: Optional[jax.Array] = None,
                      return_residual: bool = False,
                      acc: Optional[jax.Array] = None,
                      row_chunk: Optional[int] = None,
                      block_cols: int = DEFAULT_BLOCK_COLS,
                      interpret: Optional[bool] = None):
    """Transmit stage: one fused pass over this transmitter's gradients.

    grads: (N, d) stacked client gradients; h: (N,) effective fading
    (power control — and, on the streamed path, the participation mask
    and per-client aggregation weights — already folded in). Computes
    the faded partial sum ``(1/n_total) sum_n h[n] grads[n]`` in one
    read of G.

    ``quantize=False`` returns the f32 partial (d,) — the analog wire.
    ``quantize=True`` runs the quantize-on-write epilogue and returns
    ``(payload, scales)``: int8 (d,) and one f32 scale per LANE-wide
    block ((d // 128,)); ``r`` must then be the (d,) uniform [0, 1)
    stochastic-rounding draws (``repro.core.channel.sr_inputs``) unless
    ``stochastic=False`` (round-to-nearest). d must be a multiple of
    128 in quantized mode — every slab/slice is, by the slab padding
    contract.

    ``qmode`` selects the quantizer: ``"int8"`` (symmetric max|x|/127,
    stochastic rounding) or ``"sign"`` (1-bit signSGD: payload =
    sign(x) in {-1, 0, +1} on the int8 wire, scale = blockwise mean|x|;
    deterministic, ``r`` may be None). Both dequantize through the same
    ``ota_receive_slab``. ``zero_fold=True`` (sign only) selects the
    1-bit-packable sign variant: q in {-1, +1} (exact zeros fold to
    +1), all-zero blocks scale 0 — see the module docstring.

    ``sr_seed`` (int8 + stochastic only) switches the epilogue to
    IN-KERNEL rounding draws: pass the int32 scalar from
    ``repro.core.channel.sr_kernel_seed`` instead of ``r`` (which must
    then be None — the host draws are never materialized). Compiled
    launches only; the pltpu PRNG does not lower in interpret mode, so
    ``interpret=True`` (or auto-resolving to it) raises.

    **Error feedback**: ``ef`` is this transmitter's (d,) carried
    residual — it is added into the faded partial BEFORE quantization.
    ``return_residual=True`` appends the fresh residual
    ``x - dequant(quant(x))`` (x the EF-augmented partial) to the
    return: ``(payload, scales, residual)`` — still one read of G.

    **Streamed client axis** (see the module docstring): ``acc`` is a
    (d,) f32 carry — the running partial sum of the chunks already
    transmitted — and ``row_chunk`` tiles the client rows through the
    grid's client-chunk dimension (defaults to all rows: one row step,
    whose ``0 + sum`` accumulation is bitwise-equal to the resident
    kernel). Either argument selects the accumulating kernel; both are
    f32-only (``quantize=True`` raises — quantize the completed f32
    partial through a single-row launch instead, so every entry is
    quantized exactly once).
    """
    interpret = resolve_interpret(interpret)
    n, d = grads.shape
    if n_total is None:
        n_total = n
    block_cols = coarse_block(d, block_cols, interpret)
    streamed = acc is not None or row_chunk is not None
    if streamed and quantize:
        raise ValueError(
            "quantize=True cannot stream/accumulate (acc=/row_chunk=): the "
            "quantize-on-write epilogue must see the COMPLETED partial sum "
            "(one quantization step per entry, the wire contract); "
            "accumulate the f32 partial across chunks first, then quantize "
            "it with a single-row quantize=True launch")
    h2 = h.reshape(n, 1).astype(jnp.float32)

    if not quantize:
        d_pad = -(-d // block_cols) * block_cols
        gp = jnp.pad(grads, ((0, 0), (0, d_pad - d)))
        if not streamed:
            out = pl.pallas_call(
                functools.partial(_tx_kernel, n_clients=n_total),
                grid=(d_pad // block_cols,),
                in_specs=[
                    pl.BlockSpec((n, block_cols), lambda i: (0, i)),
                    pl.BlockSpec((n, 1), lambda i: (0, 0)),
                ],
                out_specs=pl.BlockSpec((1, block_cols), lambda i: (0, i)),
                out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
                interpret=interpret,
            )(gp, h2)
            return out.reshape(-1)[:d]

        rc = n if row_chunk is None else min(row_chunk, n)
        if rc < 1:
            raise ValueError(f"row_chunk must be >= 1, got {row_chunk}")
        if acc is None:
            acc = jnp.zeros((d,), jnp.float32)
        if acc.shape != (d,):
            raise ValueError(f"acc must be the ({d},) running partial sum, "
                             f"got {acc.shape}")
        # Zero rows contribute exactly 0 to the accumulation, so padding
        # the client axis up to a row-chunk multiple is value-neutral.
        n_pad = -(-n // rc) * rc
        gp = jnp.pad(gp, ((0, n_pad - n), (0, 0)))
        hp = jnp.pad(h2, ((0, n_pad - n), (0, 0)))
        ap = jnp.pad(acc.astype(jnp.float32),
                     (0, d_pad - d)).reshape(1, d_pad)
        out = pl.pallas_call(
            functools.partial(_tx_stream_kernel, n_clients=n_total),
            grid=(d_pad // block_cols, n_pad // rc),
            in_specs=[
                pl.BlockSpec((rc, block_cols), lambda j, r_: (r_, j)),
                pl.BlockSpec((rc, 1), lambda j, r_: (r_, 0)),
                pl.BlockSpec((1, block_cols), lambda j, r_: (0, j)),
            ],
            out_specs=pl.BlockSpec((1, block_cols), lambda j, r_: (0, j)),
            out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
            interpret=interpret,
        )(gp, hp, ap)
        return out.reshape(-1)[:d]

    if d % LANE != 0:
        raise ValueError(
            f"quantized transmit needs d to be a multiple of {LANE} "
            f"(the per-block scale width), got {d}; slabs satisfy this "
            "by construction")
    if qmode not in ("int8", "sign"):
        raise ValueError(f'unknown qmode {qmode!r}; options: "int8", "sign"')
    if zero_fold and qmode != "sign":
        raise ValueError("zero_fold is a sign-quantizer variant; "
                         f"qmode is {qmode!r}")
    inkernel_sr = sr_seed is not None
    if inkernel_sr:
        if not (qmode == "int8" and stochastic):
            raise ValueError(
                "sr_seed selects in-kernel stochastic rounding: it needs "
                "qmode='int8' with stochastic=True")
        if r is not None:
            raise ValueError(
                "pass EITHER the host-drawn uniforms r (the parity "
                "oracle) OR the in-kernel seed sr_seed, not both")
        if interpret:
            raise ValueError(
                "sr_seed needs a compiled launch: the pltpu PRNG does "
                "not lower in interpret mode — use the host-drawn r "
                "path there (it is the parity oracle)")
    elif (qmode == "int8" and stochastic
            and (r is None or r.shape != (d,))):
        raise ValueError("stochastic rounding needs r of shape "
                         f"({d},), got {None if r is None else r.shape}")
    if ef is not None and ef.shape != (d,):
        raise ValueError(f"ef must be the ({d},) carried residual, "
                         f"got {ef.shape}")
    d_pad = -(-d // block_cols) * block_cols
    gp = jnp.pad(grads, ((0, 0), (0, d_pad - d)))

    use_ef = ef is not None
    spec_row = pl.BlockSpec((1, block_cols), lambda i: (0, i))
    in_specs = [
        pl.BlockSpec((n, block_cols), lambda i: (0, i)),
        pl.BlockSpec((n, 1), lambda i: (0, 0)),
    ]
    operands = [gp, h2]
    if inkernel_sr:
        in_specs.append(pl.BlockSpec((1, 1), lambda i: (0, 0),
                                     memory_space=pltpu.SMEM))
        operands.append(jnp.asarray(sr_seed, jnp.int32).reshape(1, 1))
    else:
        if r is None:
            r = jnp.zeros((d,), jnp.float32)
        in_specs.append(spec_row)
        operands.append(jnp.pad(r, (0, d_pad - d)).reshape(1, d_pad))
    if use_ef:
        in_specs.append(spec_row)
        operands.append(jnp.pad(ef.astype(jnp.float32),
                                (0, d_pad - d)).reshape(1, d_pad))
    out_specs = [
        spec_row,
        pl.BlockSpec((1, block_cols // LANE), lambda i: (0, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((1, d_pad), jnp.int8),
        jax.ShapeDtypeStruct((1, d_pad // LANE), jnp.float32),
    ]
    if return_residual:
        out_specs.append(spec_row)
        out_shape.append(jax.ShapeDtypeStruct((1, d_pad), jnp.float32))
    outs = pl.pallas_call(
        functools.partial(_tx_quant_kernel, n_clients=n_total,
                          stochastic=stochastic, qmode=qmode, ef=use_ef,
                          resid=return_residual, zero_fold=zero_fold,
                          inkernel_sr=inkernel_sr),
        grid=(d_pad // block_cols,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    q, s = outs[0], outs[1]
    ret = (q.reshape(-1)[:d], s.reshape(-1)[:d // LANE])
    if return_residual:
        ret = ret + (outs[2].reshape(-1)[:d],)
    return ret


def sign_words(d: int, *, planes: bool = False) -> int:
    """Packed word count for a d-coordinate sign payload: d // 32
    uint32 words for the 1-bit zero-folded wire, twice that for the
    sign + nonzero bitplane pair."""
    if d % 32 != 0:
        raise ValueError(f"packing needs d to be a multiple of 32, got {d}")
    return (2 if planes else 1) * (d // 32)


def _bit_pos():
    return jnp.arange(32, dtype=jnp.uint32)  # XLA constant-folds this


def pack_sign_slab(payload: jax.Array, *, planes: bool = False) -> jax.Array:
    """Pack a {-1, 0, +1} int8 sign payload (..., d) into uint32 words
    (..., sign_words(d, planes)) — the transmit epilogue of the packed
    sign wire (see the module docstring).

    ``planes=False``: the sign plane alone — bit j of word w is 1 iff
    ``payload[32 w + j] < 0``. 1 bit/coord; zeros pack as +1, which is
    only faithful for the ``zero_fold=True`` quantizer (whose payloads
    carry no zeros and whose all-zero blocks ship scale 0).
    ``planes=True``: sign plane words followed by nonzero-mask plane
    words along the last axis — 2 bits/coord, any {-1, 0, +1} payload
    round-trips bitwise.
    """
    d = payload.shape[-1]
    nw = sign_words(d, planes=False)
    pos = _bit_pos()

    def plane(mask):
        b = mask.astype(jnp.uint32).reshape(*payload.shape[:-1], nw, 32)
        return jnp.sum(b << pos, axis=-1, dtype=jnp.uint32)

    sign_plane = plane(payload < 0)
    if not planes:
        return sign_plane
    return jnp.concatenate([sign_plane, plane(payload != 0)], axis=-1)


def unpack_sign_slab(words: jax.Array, d: int, *,
                     planes: bool = False) -> jax.Array:
    """Inverse of ``pack_sign_slab``: (..., sign_words(d, planes))
    uint32 words back to the (..., d) int8 sign payload the receive
    kernel dequantizes. The 1-bit wire decodes to {-1, +1} only (zeros
    were folded at the quantizer); the 2-plane wire restores exact
    {-1, 0, +1}."""
    nw = sign_words(d, planes=planes)
    if words.shape[-1] != nw:
        raise ValueError(f"expected {nw} packed words for d={d} "
                         f"(planes={planes}), got {words.shape[-1]}")
    pos = _bit_pos()

    def bits(w):
        b = (w[..., None] >> pos) & jnp.uint32(1)
        return (b > 0).reshape(*w.shape[:-1], w.shape[-1] * 32)

    if not planes:
        return jnp.where(bits(words), -1, 1).astype(jnp.int8)
    neg = bits(words[..., :nw // 2])
    nz = bits(words[..., nw // 2:])
    return jnp.where(nz, jnp.where(neg, -1, 1), 0).astype(jnp.int8)


def _rx_kernel(*refs, alpha: float, scale: float, stats: bool):
    q_ref, s_ref, u_ref, e_ref, out_ref = refs[:5]
    q = q_ref[...].astype(jnp.float32)              # (R, bc)
    s = s_ref[...]                                  # (R, nb)
    rows, bc = q.shape
    deq = q.reshape(rows, bc // LANE, LANE) * s[..., None]
    agg = jnp.sum(deq, axis=0).reshape(1, bc)       # superposed payloads
    xi = cms_transform(u_ref[...], e_ref[...], alpha)
    out_ref[...] = agg + scale * xi
    if stats:
        refs[5][...] = _residual_stats_row(xi, scale)


def ota_receive_slab(payload: jax.Array, scales: jax.Array, u: jax.Array,
                     e: jax.Array, *, alpha: float, scale: float,
                     packed: Optional[str] = None,
                     pilot_stats: bool = False,
                     block_cols: int = DEFAULT_BLOCK_COLS,
                     interpret: Optional[bool] = None):
    """Receive stage: dequantize + superpose R payload rows, then inject
    the alpha-stable interference — one fused pass.

    payload: (R, d) int8 — R transmitters' wire payloads (after the MAC
    collective each device holds the R rows addressed to its slice;
    single-device R == 1); scales: (R, d // 128) f32 per-block scales;
    u, e: (d,) CMS interference inputs. ``scale == 0`` disables the
    interference (e.g. for reducing clean-gradient statistics over the
    same wire). Returns (d,) f32, or ``(out, stats)`` with the (3,)
    residual log-moment statistics when ``pilot_stats=True`` (the fused
    epilogue; on the sharded engine each device reduces its own slice
    and the 3-vectors psum).

    ``packed="fold"|"planes"`` accepts the bit-packed sign wire
    instead: payload is then the (R, sign_words(d, ...)) uint32 words
    ``pack_sign_slab`` produced (d inferred from ``scales``), unpacked
    in the prologue before the fused dequantize launch.
    """
    if not (1.0 < alpha <= 2.0):
        raise ValueError(f"tail index alpha must be in (1, 2], got {alpha}")
    interpret = resolve_interpret(interpret)
    if packed is not None:
        if packed not in ("fold", "planes"):
            raise ValueError(f'unknown packed wire {packed!r}; '
                             'options: "fold", "planes"')
        if payload.dtype != jnp.uint32:
            raise ValueError("packed payloads are uint32 words, got "
                             f"{payload.dtype}")
        d = scales.shape[1] * LANE
        payload = unpack_sign_slab(payload, d, planes=(packed == "planes"))
    rows, d = payload.shape
    if d % LANE != 0:
        raise ValueError(f"receive needs d to be a multiple of {LANE}, "
                         f"got {d}")
    if scales.shape != (rows, d // LANE):
        raise ValueError(f"scales must be ({rows}, {d // LANE}), "
                         f"got {scales.shape}")
    block_cols = coarse_block(d, block_cols, interpret)
    d_pad = -(-d // block_cols) * block_cols
    qp = jnp.pad(payload, ((0, 0), (0, d_pad - d)))
    sp = jnp.pad(scales, ((0, 0), (0, (d_pad - d) // LANE)))
    up = jnp.pad(u, (0, d_pad - d)).reshape(1, d_pad)
    ep = jnp.pad(e, (0, d_pad - d), constant_values=1.0).reshape(1, d_pad)

    grid = (d_pad // block_cols,)
    out_specs = pl.BlockSpec((1, block_cols), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((1, d_pad), jnp.float32)
    if pilot_stats:
        out_specs = [out_specs, pl.BlockSpec((1, LANE), lambda i: (i, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((grid[0], LANE), jnp.float32)]
    outs = pl.pallas_call(
        functools.partial(_rx_kernel, alpha=alpha, scale=scale,
                          stats=pilot_stats),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block_cols), lambda i: (0, i)),
            pl.BlockSpec((rows, block_cols // LANE), lambda i: (0, i)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(qp, sp, up, ep)
    if pilot_stats:
        return outs[0].reshape(-1)[:d], _sum_stats_rows(outs[1])
    return outs.reshape(-1)[:d]
