"""Core ADOTA-FL library: OTA channel, adaptive server optimizers, FL loop."""

from repro.core.adaptive import (AdaptiveConfig, ServerOptimizer, ServerOptState,
                                 adagrad_ota, adam_ota, amsgrad_ota,
                                 apply_slab_update, fedavg, fedavgm,
                                 make_server_optimizer, yogi_ota)
from repro.core.channel import (OTAChannelConfig, UplinkConfig, cms_inputs,
                                cms_transform, sample_alpha_stable,
                                sample_fading, sample_interference, sr_inputs,
                                upsilon)
from repro.core.fl import (FLConfig, RoundMetrics, donation_report,
                           init_server, make_round_step,
                           make_sharded_round_step, make_slab_round_runner,
                           make_slab_round_step, run_rounds, run_rounds_slab)
from repro.core.ota import (add_interference, downlink_quantize_slab,
                            downlink_sr_slab_inputs, faded_loss_weights,
                            interference_log_moment_stats,
                            ota_aggregate_slab, ota_aggregate_stacked,
                            ota_psum, uplink_sr_slab_inputs)
from repro.core.shard import (client_axes_of, n_client_shards,
                              shard_round_step)
from repro.core.slab import (SlabSpec, make_slab_spec, slab_to_tree,
                             stack_to_slab, tree_to_slab, zeros_slab)
from repro.core.stream import (PART_FOLD, StreamParts, participation_mask,
                               round_participation, streamed_round_parts)
from repro.core.slab_state import (SlabTrainState, init_train_state,
                                   pack_train_state, unpack_train_state)
from repro.core.tail_index import (alpha_from_log_moments, effective_alpha,
                                   hill_estimate, log_moment_estimate,
                                   log_moment_stats, update_alpha_ema)

__all__ = [
    "AdaptiveConfig", "ServerOptimizer", "ServerOptState", "adagrad_ota",
    "adam_ota", "fedavg", "fedavgm", "make_server_optimizer", "yogi_ota",
    "amsgrad_ota", "apply_slab_update", "OTAChannelConfig", "UplinkConfig",
    "cms_inputs", "cms_transform", "sample_alpha_stable", "sample_fading",
    "sample_interference", "sr_inputs", "upsilon", "FLConfig", "RoundMetrics",
    "init_server", "make_round_step", "make_sharded_round_step", "run_rounds",
    "add_interference", "downlink_quantize_slab", "downlink_sr_slab_inputs",
    "faded_loss_weights", "ota_aggregate_slab",
    "ota_aggregate_stacked", "ota_psum", "uplink_sr_slab_inputs",
    "SlabSpec", "make_slab_spec",
    "slab_to_tree", "stack_to_slab", "tree_to_slab", "zeros_slab",
    "hill_estimate", "log_moment_estimate", "alpha_from_log_moments",
    "log_moment_stats", "update_alpha_ema", "effective_alpha",
    "interference_log_moment_stats", "client_axes_of",
    "n_client_shards", "shard_round_step", "SlabTrainState",
    "init_train_state", "pack_train_state", "unpack_train_state",
    "make_slab_round_step", "make_slab_round_runner", "run_rounds_slab",
    "donation_report",
    "PART_FOLD", "StreamParts", "participation_mask", "round_participation",
    "streamed_round_parts",
]
