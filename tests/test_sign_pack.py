"""Bit-packed sign wire + in-kernel SR (PR 8).

Contracts under test:

* ``pack_sign_slab`` / ``unpack_sign_slab`` round-trip bitwise on their
  valid payloads — {-1, +1} on the 1-bit 'fold' wire, {-1, 0, +1} on
  the 2-bit 'planes' wire — for any leading batch shape (the sharded
  exchange packs (P, 2, d) stacks);
* routing a payload through the packed wire never perturbs the
  received values: packed receive == unpacked receive BITWISE on both
  the kernel wrapper and the ref oracle;
* the zero-folded sign quantizer keeps the slab zero-tail contract on
  the 1-bit wire: all-zero 128-blocks ship scale 0, so the padded tail
  dequantizes to exactly 0 even though its sign bits decode to +1;
* the 'planes' container is value-identical to the PR 7 int8 container
  (same quantizer, lossless wire): their trajectories are BITWISE
  equal on both engines;
* wire byte counts: the arrays the exchange ships measure exactly what
  the ``train_loop_bench`` byte model claims, and the 1-bit wire cuts
  the sign payload 8x vs the int8 container;
* in-kernel stochastic rounding is compiled-only: ``sr_seed`` traces
  under ``jax.eval_shape`` with the host-draw output contract, raises
  in interpret mode, and ``sr_kernel_seed`` mirrors the host (2,)
  noisy/clean row convention.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_train_state,
                        make_slab_round_step)
from repro.core.channel import SR_FOLD, sr_kernel_seed
from repro.kernels.ota_channel import (ota_receive_slab, ota_transmit_slab,
                                       pack_sign_slab, sign_words,
                                       unpack_sign_slab)
from repro.kernels.ref import ota_receive_ref

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

N = 8
SHAPES = [(3, 45), (130,), (1,)]


def _params():
    ks = jax.random.split(jax.random.key(0), len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _batches(params, n=N):
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (n,) + p.shape),
        params)


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _configs(sign_pack="fold", ef=True):
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          uplink=UplinkConfig(mode="sign",
                                              error_feedback=ef,
                                              sign_pack=sign_pack))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    return ch, ad, FLConfig(n_clients=N)


def _trajectory(ch, ad, fl, backend, rounds=2):
    params = _params()
    batches = _batches(params)
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend=backend)
    st = init_train_state(ad, params,
                          error_feedback=ch.uplink.error_feedback)
    for t in range(rounds):
        st, ms = step(st, jax.random.fold_in(jax.random.key(7), t), batches)
    return st, ms


def _bench_byte_models():
    """Import the bench byte models without leaking the forced
    host-device XLA flag the bench module installs at import (other
    tests and their subprocesses must keep the real device view)."""
    saved = os.environ.get("XLA_FLAGS")
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    try:
        from benchmarks.train_loop_bench import (_loop_bytes,
                                                 _measured_uplink_bytes)
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved
    return _loop_bytes, _measured_uplink_bytes


# ---------------------------------------------------------------------------
# Pack / unpack round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(256,), (2, 384), (3, 2, 128)])
def test_fold_roundtrip_bitwise(shape):
    key = jax.random.key(1)
    q = jnp.where(jax.random.bernoulli(key, 0.5, shape), 1, -1
                  ).astype(jnp.int8)
    words = pack_sign_slab(q)
    assert words.dtype == jnp.uint32
    assert words.shape == shape[:-1] + (shape[-1] // 32,)
    np.testing.assert_array_equal(
        np.asarray(unpack_sign_slab(words, shape[-1])), np.asarray(q))


@pytest.mark.parametrize("shape", [(256,), (2, 384), (3, 2, 128)])
def test_planes_roundtrip_bitwise(shape):
    key = jax.random.key(2)
    q = (jax.random.randint(key, shape, -1, 2)).astype(jnp.int8)
    assert int(jnp.sum(q == 0)) > 0          # zeros actually exercised
    words = pack_sign_slab(q, planes=True)
    assert words.shape == shape[:-1] + (2 * (shape[-1] // 32),)
    np.testing.assert_array_equal(
        np.asarray(unpack_sign_slab(words, shape[-1], planes=True)),
        np.asarray(q))


def test_fold_zeros_decode_plus_one():
    """The 1-bit wire has no zero codepoint: zeros pack as +1 (which is
    why only the zero_fold quantizer — whose payloads carry no zeros —
    may use it)."""
    q = jnp.array([0, -1, 1, 0], jnp.int8)
    out = unpack_sign_slab(pack_sign_slab(jnp.tile(q, 32)), 128)
    np.testing.assert_array_equal(np.asarray(out[:4]),
                                  np.array([1, -1, 1, 1], np.int8))


def test_sign_words_validates():
    assert sign_words(256) == 8
    assert sign_words(256, planes=True) == 16
    with pytest.raises(ValueError, match="multiple of 32"):
        sign_words(100)


# ---------------------------------------------------------------------------
# Packed receive == unpacked receive, kernel and ref
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", ["fold", "planes"])
def test_packed_receive_bitwise(packed):
    d = 512
    ks = jax.random.split(jax.random.key(3), 4)
    g = jax.random.normal(ks[0], (2, d))
    rows = [ota_transmit_slab(row[None], jnp.ones((1,)), quantize=True,
                              qmode="sign", zero_fold=(packed == "fold"))
            for row in g]
    payload = jnp.stack([r[0] for r in rows])
    scales = jnp.stack([r[1] for r in rows])
    u = jax.random.uniform(ks[1], (d,), minval=-1.5, maxval=1.5)
    e = -jnp.log(jax.random.uniform(ks[2], (d,), minval=1e-6))
    words = pack_sign_slab(payload, planes=(packed == "planes"))
    for fn in (ota_receive_slab, ota_receive_ref):
        plain = fn(payload, scales, u, e, alpha=1.5, scale=0.1)
        via_wire = fn(words, scales, u, e, alpha=1.5, scale=0.1,
                      packed=packed)
        np.testing.assert_array_equal(np.asarray(plain),
                                      np.asarray(via_wire))


def test_packed_receive_validates():
    d = 256
    payload = jnp.zeros((1, d), jnp.int8)
    scales = jnp.zeros((1, d // 128), jnp.float32)
    u = jnp.zeros((d,))
    e = jnp.ones((d,))
    with pytest.raises(ValueError, match="uint32"):
        ota_receive_slab(payload, scales, u, e, alpha=1.5, scale=0.1,
                         packed="fold")
    with pytest.raises(ValueError, match="unknown packed"):
        ota_receive_slab(jnp.zeros((1, d // 32), jnp.uint32), scales, u, e,
                         alpha=1.5, scale=0.1, packed="zip")


def test_zero_tail_survives_packed_wire():
    """A slab tail of exact zeros: the zero_fold quantizer ships scale 0
    for its all-zero blocks, so the tail dequantizes to exactly 0 off
    the 1-bit wire (whose sign bits there decode to +1)."""
    d = 512
    tail = d // 2
    g = jnp.concatenate([jax.random.normal(jax.random.key(4), (d - tail,)),
                         jnp.zeros((tail,))])[None]
    payload, scales = ota_transmit_slab(g, jnp.ones((1,)), quantize=True,
                                        qmode="sign", zero_fold=True)
    assert float(jnp.max(jnp.abs(scales[(d - tail) // 128:]))) == 0.0
    words = pack_sign_slab(payload[None])
    out = ota_receive_slab(words, scales[None], jnp.zeros((d,)),
                           jnp.ones((d,)), alpha=1.5, scale=0.0,
                           packed="fold")
    np.testing.assert_array_equal(np.asarray(out[d - tail:]),
                                  np.zeros(tail, np.float32))


# ---------------------------------------------------------------------------
# Containers across backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_planes_container_equals_int8_container_bitwise(backend):
    """'planes' is a lossless re-encoding of the PR 7 int8 container:
    same quantizer, bitwise round-trip. The pallas trajectories are
    BITWISE equal — the MAC lives in a fixed kernel, so the wire
    encoding cannot perturb it. On the jnp reference the aggregate is
    bitwise too (checked component-wise in the receive tests above),
    but inserting pack/unpack ops into the single jitted round-step
    graph shifts XLA's fusion boundaries on CPU, which re-associates
    downstream float chains — so the whole-trajectory check there is
    ULP-tight allclose rather than array_equal."""
    ad = fl = None
    st = {}
    for sp in ("planes", "int8"):
        ch, ad, fl = _configs(sign_pack=sp)
        st[sp], _ = _trajectory(ch, ad, fl, backend)
    for a, b in zip((st["planes"].w, *st["planes"].opt, st["planes"].ef),
                    (st["int8"].w, *st["int8"].opt, st["int8"].ef)):
        if backend == "pallas":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-7, atol=2e-7)


def test_fold_cell_jnp_pallas_parity():
    """The 1-bit fold wire is a (slightly) different quantizer, so it
    gets its own cross-engine parity cell at the standard tier."""
    ch, ad, fl = _configs(sign_pack="fold")
    st_j, m_j = _trajectory(ch, ad, fl, "jnp")
    st_p, m_p = _trajectory(ch, ad, fl, "pallas")
    for a, b in zip((st_j.w, *st_j.opt, st_j.ef),
                    (st_p.w, *st_p.opt, st_p.ef)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(m_j.loss), float(m_p.loss), rtol=1e-5)


def test_uplink_config_validates_sign_pack():
    with pytest.raises(ValueError, match="sign_pack"):
        UplinkConfig(mode="sign", sign_pack="zip")
    assert UplinkConfig(mode="sign").packed_sign == "fold"
    assert UplinkConfig(mode="sign", sign_pack="int8").packed_sign is None
    assert UplinkConfig(mode="int8").packed_sign is None
    assert UplinkConfig(mode="sign").zero_fold
    assert not UplinkConfig(mode="sign", sign_pack="planes").zero_fold


# ---------------------------------------------------------------------------
# Wire byte counts vs the bench model
# ---------------------------------------------------------------------------

def test_wire_bytes_match_bench_model():
    loop_bytes, measured = _bench_byte_models()
    d, p, k = 1 << 14, 2, 2
    for uplink, sp in (("f32", "fold"), ("int8", "fold"),
                       ("sign", "fold"), ("sign", "planes"),
                       ("sign", "int8")):
        model = loop_bytes(d, N, p, k, True, uplink, "f32", sp)
        assert measured(d, p, uplink, sp) == model["uplink_bytes_per_round"]
    # the 1-bit wire cuts the sign PAYLOAD 8x vs the int8 container
    # (scale rows identical on both)
    scale_b = 2 * (d // 128) * 4
    fold = loop_bytes(d, N, p, k, True, "sign", "f32", "fold")
    c8 = loop_bytes(d, N, p, k, True, "sign", "f32", "int8")
    assert (c8["uplink_bytes_per_round"] - scale_b) == \
        8 * (fold["uplink_bytes_per_round"] - scale_b)


# ---------------------------------------------------------------------------
# In-kernel stochastic rounding (compiled-only)
# ---------------------------------------------------------------------------

def test_sr_kernel_seed_contract():
    key = jax.random.key(5)
    s = sr_kernel_seed(key)
    assert s.shape == (2,) and s.dtype == jnp.int32
    # deterministic, noisy != clean, shard-folded streams distinct
    np.testing.assert_array_equal(np.asarray(s),
                                  np.asarray(sr_kernel_seed(key)))
    assert int(s[0]) != int(s[1])
    assert int(sr_kernel_seed(key, shard_index=1)[0]) != int(s[0])
    # keyed under the same SR_FOLD domain as the host draws
    k = jax.random.fold_in(jax.random.fold_in(key, 0), SR_FOLD)
    expect = jax.random.randint(k, (2,), jnp.iinfo(jnp.int32).min,
                                jnp.iinfo(jnp.int32).max, dtype=jnp.int32)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(expect))


def test_inkernel_sr_traces_compiled_and_rejects_interpret():
    d = 512
    g = jnp.zeros((1, d))
    h = jnp.ones((1,))
    seed = sr_kernel_seed(jax.random.key(6))[0]

    def tx(g, h, seed):
        return ota_transmit_slab(g, h, quantize=True, sr_seed=seed,
                                 interpret=False)

    out = jax.eval_shape(tx, g, h, seed)
    assert out[0].shape == (d,) and out[0].dtype == jnp.int8
    assert out[1].shape == (d // 128,) and out[1].dtype == jnp.float32

    with pytest.raises(ValueError, match="interpret"):
        ota_transmit_slab(g, h, quantize=True, sr_seed=seed,
                          interpret=True)
    with pytest.raises(ValueError, match="not both"):
        ota_transmit_slab(g, h, quantize=True, sr_seed=seed,
                          r=jnp.zeros((d,)), interpret=False)
    with pytest.raises(ValueError, match="int8"):
        ota_transmit_slab(g, h, quantize=True, qmode="sign",
                          stochastic=False, sr_seed=seed, interpret=False)


def test_uplink_config_validates_sr_inkernel():
    with pytest.raises(ValueError, match="sr_inkernel"):
        UplinkConfig(mode="sign", sr_inkernel=True)
    with pytest.raises(ValueError, match="sr_inkernel"):
        UplinkConfig(mode="int8", stochastic_rounding=False,
                     sr_inkernel=True)
    assert UplinkConfig(mode="int8", sr_inkernel=True).sr_inkernel


# ---------------------------------------------------------------------------
# Zero-tail contract on the fold wire (regression: mixed final block)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fold_mixed_block_tail_restored(backend):
    """A slab whose padding shares its final 128-block with real coords
    has a NONZERO scale there, so the folded +1 padding bits dequantize
    to +scale in-kernel — the slab layer must re-mask them
    (ota.restore_zero_tail) or the resident engines accumulate tail
    drift the pytree-materialising oracle discards (the jnp/pallas
    parity failure this regression pins). Gradient AND EF residual
    tails must come back exactly zero, on both engines."""
    from repro.core.ota import ota_aggregate_slab
    from repro.core.slab import make_slab_spec

    params = {"w": jax.random.normal(jax.random.key(0), (200,)),
              "b": jax.random.normal(jax.random.key(1), (66,))}
    spec = make_slab_spec(params)
    assert spec.total % 128 != 0      # the mixed-block case
    n = 4
    grads = jax.tree.map(
        lambda p: jnp.stack([p * (0.1 * (i + 1)) for i in range(n)]),
        params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1, backend=backend,
                          uplink=UplinkConfig(mode="sign",
                                              sign_pack="fold",
                                              error_feedback=True))
    ef0 = jnp.zeros((spec.padded,), jnp.float32)
    g, _, _, _, ef_new = ota_aggregate_slab(jax.random.key(5), ch, grads,
                                            spec, ef=ef0)
    np.testing.assert_array_equal(np.asarray(g)[spec.total:], 0.0)
    np.testing.assert_array_equal(
        np.asarray(ef_new)[..., spec.total:], 0.0)
    # the real coords still carry signal
    assert np.abs(np.asarray(g)[:spec.total]).max() > 0
