"""Attention: MHA/GQA, causal + sliding-window masking, KV-cache decode,
cross-attention, and a chunked online-softmax path (pure-JAX flash) that
bounds the score-matrix working set — the memory-roofline lever used in
§Perf.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, dense, dense_init, rmsnorm,
                                 rmsnorm_init)

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window size; None = full
    causal: bool = True
    kv_chunk: Optional[int] = None        # online-softmax KV chunk (perf lever)
    window_block: bool = False            # block-local windowed attention:
                                          # Q in window-sized blocks, keys =
                                          # {prev, self} blocks only. O(S*W)
                                          # scores instead of O(S^2) or
                                          # O(S*chunk)*n_chunks (perf lever)


def attn_init(key, cfg: AttentionConfig, dtype=jnp.bfloat16) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, cfg.d_model, (cfg.n_heads, cfg.head_dim), dtype,
                         use_bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, (cfg.n_kv_heads, cfg.head_dim), dtype,
                         use_bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, (cfg.n_kv_heads, cfg.head_dim), dtype,
                         use_bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.n_heads * cfg.head_dim, (cfg.d_model,), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim)
        p["k_norm"] = rmsnorm_init(cfg.head_dim)
    return p


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: Optional[int], k_valid: Optional[jax.Array] = None
               ) -> jax.Array:
    """Additive mask bias (..., S_q, S_k) from query/key absolute positions."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    if k_valid is not None:
        ok &= k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _gqa_scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    """q: (B,Sq,H,D), k: (B,Sk,K,D) -> scores (B,K,G,Sq,Sk) with H = K*G."""
    b, sq, h, d = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, sq, kheads, g, d)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                      k.astype(jnp.float32),
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(probs: jax.Array, v: jax.Array, out_dtype) -> jax.Array:
    """probs: (B,K,G,Sq,Sk), v: (B,Sk,K,D) -> (B,Sq,H,D)."""
    b, kheads, g, sq, _ = probs.shape
    o = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return o.reshape(b, sq, kheads * g, v.shape[-1]).astype(out_dtype)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, q_pos: jax.Array,
           k_pos: jax.Array, causal: bool, window: Optional[int],
           k_valid: Optional[jax.Array] = None,
           kv_chunk: Optional[int] = None,
           window_block: bool = False) -> jax.Array:
    """Masked GQA attention. Shapes: q (B,Sq,H,D); k,v (B,Sk,K,D);
    q_pos (B,Sq) or (Sq,); k_pos (B,Sk) or (Sk,); k_valid optional (B,Sk).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    if (window_block and window is not None and causal
            and q.shape[1] == k.shape[1] and q.shape[1] > 2 * window
            and k_valid is None):
        return _attend_window_blocked(q, k, v, window, scale)
    if q_pos.ndim == 1:
        q_pos = q_pos[None, :]
    if k_pos.ndim == 1:
        k_pos = k_pos[None, :]
    if kv_chunk is not None and k.shape[1] > kv_chunk:
        # chunked layout requires per-batch position rows
        k_pos_b = jnp.broadcast_to(k_pos, (k.shape[0], k.shape[1]))
        return _attend_chunked(q, k, v, q_pos, k_pos_b, causal, window,
                               k_valid, kv_chunk, scale)
    bias = _mask_bias(q_pos, k_pos, causal, window, k_valid)  # (B,Sq,Sk)
    scores = _gqa_scores(q, k, scale) + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, q.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, causal, window, k_valid,
                    chunk: int, scale: float) -> jax.Array:
    """Online-softmax over KV chunks: working set O(Sq * chunk) instead of
    O(Sq * Sk). Equivalent to flash attention's outer loop, in pure JAX."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        valid_pad = jnp.arange(n_chunks * chunk) < sk
        k_valid = (valid_pad[None, :] if k_valid is None
                   else jnp.pad(k_valid, ((0, 0), (0, pad))) & valid_pad[None, :])
        k_valid = jnp.broadcast_to(k_valid, (b, n_chunks * chunk))
    kheads = k.shape[2]
    g = h // kheads
    kc = k.reshape(b, n_chunks, chunk, kheads, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kheads, d).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    valc = (None if k_valid is None
            else k_valid.reshape(b, n_chunks, chunk).transpose(1, 0, 2))

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        if valc is None:
            kj, vj, pj = xs
            vj_valid = None
        else:
            kj, vj, pj, vj_valid = xs
        bias = _mask_bias(q_pos, pj, causal, window, vj_valid)   # (B,Sq,chunk)
        s = _gqa_scores(q, kj, scale) + bias[:, None, None]       # (B,K,G,Sq,c)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
        acc = acc * corr[..., None] + o
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kheads, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kheads, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kheads, g, sq, d), jnp.float32)
    xs = (kc, vc, pc) if valc is None else (kc, vc, pc, valc)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]                  # (B,K,G,Sq,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)


def _attend_window_blocked(q, k, v, window: int, scale: float) -> jax.Array:
    """Causal sliding-window attention in window-sized Q blocks.

    Q block i attends to K/V blocks {i-1, i}; with block size == window,
    every in-window key is covered and the position mask inside the
    2W-wide stripe enforces exactness. Working set per scan step is
    O(W * 2W) scores — independent of S (the §Perf memory lever for the
    windowed architectures at 32k/500k sequence lengths).
    Assumes self-attention with aligned positions 0..S-1.
    """
    b, s, h, d = q.shape
    kheads = k.shape[2]
    w = window
    n = -(-s // w)
    pad = n * w - s
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    qc = q.reshape(b, n, w, h, d).transpose(1, 0, 2, 3, 4)       # (n,B,W,H,D)
    kc = k.reshape(b, n, w, kheads, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, w, kheads, d).transpose(1, 0, 2, 3, 4)
    zero = jnp.zeros_like(kc[:1])
    k2 = jnp.concatenate([jnp.concatenate([zero, kc[:-1]], 0), kc], axis=2)
    v2 = jnp.concatenate([jnp.concatenate([zero, vc[:-1]], 0), vc], axis=2)
    idx = jnp.arange(n)

    def step(_, xs):
        i, qj, kj, vj = xs
        q_pos = i * w + jnp.arange(w)
        k_pos = (i - 1) * w + jnp.arange(2 * w)
        dpos = q_pos[:, None] - k_pos[None, :]
        ok = (dpos >= 0) & (dpos < w) & (k_pos >= 0)[None, :] \
            & (q_pos < s)[:, None] & (k_pos < s)[None, :]
        bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        sc = _gqa_scores(qj, kj, scale) + bias[None, None, None]
        pr = jax.nn.softmax(sc, axis=-1)
        return None, _gqa_out(pr, vj, q.dtype)

    _, out = jax.lax.scan(step, None, (idx, qc, k2, v2))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, n * w, h, d)[:, :s]


def _project_qkv(p: dict, cfg: AttentionConfig, xq: jax.Array, xkv: jax.Array,
                 q_pos: Optional[jax.Array], k_pos: Optional[jax.Array]
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = dense(p["wq"], xq)
    k = dense(p["wk"], xkv)
    v = dense(p["wv"], xkv)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.rope and q_pos is not None:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, k_pos, cfg.rope_theta)
    return q, k, v


def self_attention(p: dict, cfg: AttentionConfig, x: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """Training/prefill self-attention over the whole sequence."""
    q, k, v = _project_qkv(p, cfg, x, x, positions, positions)
    o = attend(q, k, v, positions, positions, cfg.causal, cfg.window,
               kv_chunk=cfg.kv_chunk, window_block=cfg.window_block)
    return dense(p["wo"], o.reshape(*o.shape[:-2], -1))


def cross_attention(p: dict, cfg: AttentionConfig, x: jax.Array,
                    kv_source: jax.Array) -> jax.Array:
    """Cross-attention (no mask, no rope on keys by convention here)."""
    q = dense(p["wq"], x)
    k = dense(p["wk"], kv_source)
    v = dense(p["wv"], kv_source)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    sq = jnp.arange(x.shape[1])
    sk = jnp.arange(kv_source.shape[1])
    o = attend(q, k, v, sq, sk, causal=False, window=None,
               kv_chunk=cfg.kv_chunk)
    return dense(p["wo"], o.reshape(*o.shape[:-2], -1))


# --------------------------------------------------------------------------
# KV cache (full-length and ring-buffer for sliding window).
# --------------------------------------------------------------------------

def init_kv_cache(batch: int, length: int, cfg: AttentionConfig,
                  dtype=jnp.bfloat16) -> dict:
    """length = S_max for full attention; = window for ring (windowed) cache."""
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((length,), -1, jnp.int32),   # absolute slot positions
    }


def decode_self_attention(p: dict, cfg: AttentionConfig, x: jax.Array,
                          cache: dict, pos: jax.Array) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d_model); pos: scalar absolute position.

    Full attention uses slot = pos; sliding window uses a ring buffer with
    slot = pos % window, so cache memory is O(window), not O(S).
    """
    length = cache["k"].shape[1]
    q, k, v = _project_qkv(p, cfg, x, x, jnp.full((1,), pos), jnp.full((1,), pos))
    slot = pos % length if cfg.window is not None else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                        jnp.full((1,), pos, jnp.int32), (slot,))
    k_valid = (cpos >= 0)[None, :]
    o = attend(q, ck, cv, jnp.full((1,), pos), cpos[None, :].astype(jnp.int32),
               cfg.causal, cfg.window, k_valid=k_valid, kv_chunk=cfg.kv_chunk)
    y = dense(p["wo"], o.reshape(*o.shape[:-2], -1))
    return y, {"k": ck, "v": cv, "pos": cpos}


def prefill_kv_cache(p: dict, cfg: AttentionConfig, x: jax.Array,
                     positions: jax.Array, length: int) -> Tuple[jax.Array, dict]:
    """Run prefill self-attention AND build the decode cache in one pass."""
    q, k, v = _project_qkv(p, cfg, x, x, positions, positions)
    o = attend(q, k, v, positions, positions, cfg.causal, cfg.window,
               kv_chunk=cfg.kv_chunk, window_block=cfg.window_block)
    y = dense(p["wo"], o.reshape(*o.shape[:-2], -1))
    s = x.shape[1]
    cache = init_kv_cache(x.shape[0], length, cfg, dtype=k.dtype)
    if cfg.window is not None and s > length:
        # Keep only the last `window` tokens, ring-aligned.
        keep = length
        ks, vs = k[:, -keep:], v[:, -keep:]
        ps = jnp.arange(s - keep, s, dtype=jnp.int32)
        order = jnp.argsort(ps % length)
        cache = {"k": ks[:, order], "v": vs[:, order], "pos": ps[order]}
    else:
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        cache["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.arange(s, dtype=jnp.int32), (0,))
    return y, cache
