"""AST-tier repro-lint rules: pure-stdlib checks over ``src/``.

Each rule answers one question the test suite cannot (cheaply) ask:

* ``fold-collision`` / ``fold-drift`` / ``fold-unregistered`` — is
  every ``fold_in`` domain separator unique and ledgered in
  ``repro.analysis.fold_registry``? Colliding separators correlate
  draws that must be independent, yet each corrupted stream is still
  individually uniform — invisible to numeric tests.
* ``rekey-in-round`` — does a round body mint or re-split PRNG keys?
  The three backends (jnp / pallas / pallas_sharded) agree bitwise
  only because every draw is sliced from the SAME pre-split round
  keys; a branch that re-splits locally silently forks the streams.
* ``zero-tail-restore`` — is every quantized-aggregate receive site
  that can see a ``zero_fold`` sign wire paired with
  ``restore_zero_tail``? Sign-wire padding blocks dequantize to
  ±scale, not zero, so an unmasked tail leaks into the next round's
  master weights.
* ``kernel-mirror`` — does every public Pallas kernel have an
  op-mirrored jnp oracle in ``repro.kernels.ref`` with a matching
  signature (modulo launch-geometry params)? The parity tests only
  cover kernels the oracle knows about.
* ``rekey-in-round`` and ``local-import`` findings can be waived in
  place: ``# repro-lint: allow[<rule-id>]`` on (or up to three lines
  above) the flagged line, or ``# repro-lint: lazy-import (reason)``
  for a deliberate function-local import (cycle breaks, side-effect
  deferral). Every rule honours ``allow[...]``.

Entry points: ``analyze_repo(root)`` for the live tree,
``analyze_sources({relpath: source})`` for in-memory fixtures (the
test suite), ``analyze_paths(files, root)`` for an explicit file set.
"""

from __future__ import annotations

import ast
import os
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.fold_registry import MIN_SEPARATOR, REGISTERED_FOLDS

# Rule id -> one-line description (the CLI's --list-rules catalogue).
AST_RULES = {
    "fold-collision":
        "two fold_in domain separators share a value (correlated draws)",
    "fold-drift":
        "a *_FOLD constant disagrees with / is missing from the registry",
    "fold-unregistered":
        "a fold_in separator literal >= MIN_SEPARATOR is not registered",
    "rekey-in-round":
        "PRNG key minted or re-split inside a round body (parity hazard)",
    "zero-tail-restore":
        "quantized receive with zero_fold in scope lacks restore_zero_tail",
    "kernel-mirror":
        "public Pallas kernel without a signature-matching oracle in ref.py",
    "local-import":
        "function-local import without a lazy-import waiver",
    "syntax-error":
        "file does not parse (all other rules skipped for it)",
}

# Launch-geometry / kernel-implementation params exempt from the
# kernel<->oracle signature match: grid tiling, interpret-mode policy,
# and the in-kernel SR seed (the oracle takes pre-drawn uniforms).
KERNEL_ONLY_PARAMS = {"block_cols", "block_rows", "bq", "bk",
                      "interpret", "sr_seed"}

# Modules whose function bodies are "round bodies" for rekey-in-round.
_ROUND_SCOPE_SUFFIXES = ("repro/core/ota.py", "repro/core/shard.py",
                         "repro/core/stream.py")
# Modules holding quantized-aggregate receive sites (zero-tail rule).
_ZERO_TAIL_SUFFIXES = _ROUND_SCOPE_SUFFIXES

_RECEIVE_FNS = {"ota_receive_slab", "ota_receive_ref"}

_WAIVER_TAG = "# repro-lint:"
# How many lines above a flagged statement a waiver comment may sit.
_WAIVER_REACH = 3


class _Mod:
    """One parsed source file (repo-relative posix path + AST)."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source)

    def snippet(self, line: int) -> str:
        if 0 < line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


def _norm(path: str) -> str:
    return str(path).replace(os.sep, "/")


def _is_kernel_mod(path: str) -> bool:
    return ("repro/kernels/" in path
            and not path.endswith(("/ref.py", "/interpret.py",
                                   "/__init__.py")))


def _in_round_scope(path: str) -> bool:
    return path.endswith(_ROUND_SCOPE_SUFFIXES) or _is_kernel_mod(path)


def _waived(mod: _Mod, node: ast.AST, rule: str) -> bool:
    start = getattr(node, "lineno", 1)
    end = getattr(node, "end_lineno", start) or start
    for ln in range(max(1, start - _WAIVER_REACH), end + 1):
        text = mod.lines[ln - 1] if ln <= len(mod.lines) else ""
        if _WAIVER_TAG not in text:
            continue
        # A waiver ABOVE the statement must be a standalone comment;
        # a trailing waiver (code + comment) covers only its own line.
        if ln < start and not text.lstrip().startswith("#"):
            continue
        tag = text.split(_WAIVER_TAG, 1)[1]
        if f"allow[{rule}]" in tag:
            return True
        if rule == "local-import" and "lazy-import" in tag:
            return True
    return False


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _call_name(func: ast.AST) -> Optional[str]:
    """Last path component of a call target (``ota.f`` -> ``f``)."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


def _int_const(node: ast.AST) -> Optional[int]:
    if (isinstance(node, ast.Constant) and isinstance(node.value, int)
            and not isinstance(node.value, bool)):
        return node.value
    return None


# ---------------------------------------------------------------------------
# fold rules


def _check_folds(mods: Sequence[_Mod], registry: Dict[str, int],
                 min_separator: int, registry_path: str,
                 check_registry_coverage: bool) -> List[Finding]:
    findings = []

    # Registry self-collision: two ledger entries sharing a value.
    by_value: Dict[int, str] = {}
    for name in sorted(registry):
        val = registry[name]
        if val in by_value:
            findings.append(Finding(
                registry_path, 1, "fold-collision", "error",
                f"registered separators {by_value[val]} and {name} share "
                f"the value {val:#x}", snippet=name))
        else:
            by_value[val] = name

    seen_defs: Dict[int, Tuple[str, str, int]] = {}
    defined: Set[str] = set()
    for mod in mods:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                if len(node.targets) != 1:
                    continue
                tgt = node.targets[0]
                if not (isinstance(tgt, ast.Name)
                        and tgt.id.endswith("_FOLD")):
                    continue
                val = _int_const(node.value)
                if val is None:
                    continue
                defined.add(tgt.id)
                snip = mod.snippet(node.lineno)
                if tgt.id not in registry:
                    findings.append(Finding(
                        mod.path, node.lineno, "fold-drift", "error",
                        f"{tgt.id} = {val:#x} is not ledgered in "
                        "repro.analysis.fold_registry.REGISTERED_FOLDS",
                        snippet=snip))
                elif registry[tgt.id] != val:
                    findings.append(Finding(
                        mod.path, node.lineno, "fold-drift", "error",
                        f"{tgt.id} = {val:#x} here but "
                        f"{registry[tgt.id]:#x} in the registry",
                        snippet=snip))
                prev = seen_defs.get(val)
                if prev is not None and prev[0] != tgt.id:
                    findings.append(Finding(
                        mod.path, node.lineno, "fold-collision", "error",
                        f"{tgt.id} = {val:#x} collides with {prev[0]} "
                        f"({prev[1]}:{prev[2]})", snippet=snip))
                else:
                    seen_defs.setdefault(val, (tgt.id, mod.path,
                                               node.lineno))
            elif isinstance(node, ast.Call):
                if _call_name(node.func) != "fold_in":
                    continue
                if len(node.args) < 2:
                    continue
                sep = node.args[1]
                lit = _int_const(sep)
                if lit is not None:
                    if (lit >= min_separator
                            and lit not in registry.values()
                            and not _waived(mod, node,
                                            "fold-unregistered")):
                        findings.append(Finding(
                            mod.path, node.lineno, "fold-unregistered",
                            "error",
                            f"fold_in separator {lit:#x} is not a "
                            "registered domain separator — name it and "
                            "add it to repro.analysis.fold_registry",
                            snippet=mod.snippet(node.lineno)))
                elif (isinstance(sep, ast.Name)
                        and sep.id.endswith("_FOLD")
                        and sep.id not in registry
                        and not _waived(mod, node, "fold-unregistered")):
                    findings.append(Finding(
                        mod.path, node.lineno, "fold-unregistered",
                        "error",
                        f"fold_in separator {sep.id} is not registered "
                        "in repro.analysis.fold_registry",
                        snippet=mod.snippet(node.lineno)))

    if check_registry_coverage:
        for name in sorted(set(registry) - defined):
            findings.append(Finding(
                registry_path, 1, "fold-drift", "error",
                f"{name} is registered but no module in src/ defines it "
                "— delete the stale registry entry or restore the "
                "constant", snippet=name))
    return findings


# ---------------------------------------------------------------------------
# rekey-in-round


def _function_scopes(tree: ast.Module) -> Iterable[ast.AST]:
    """Top-level function/class bodies (each walked exactly once)."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            yield node


def _check_rekey(mod: _Mod) -> List[Finding]:
    if not _in_round_scope(mod.path):
        return []
    findings = []
    for scope in _function_scopes(mod.tree):
        for node in ast.walk(scope):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func) or ""
            mint = (dotted.endswith("random.PRNGKey")
                    or dotted.endswith("random.key"))
            resplit = dotted.endswith("random.split")
            if not (mint or resplit):
                continue
            if _waived(mod, node, "rekey-in-round"):
                continue
            if mint:
                msg = (f"{dotted} mints a fresh PRNG key inside a round "
                       "body — round randomness must derive from the "
                       "caller's round key")
                sev = "error"
            else:
                msg = (f"{dotted} re-splits a key inside a round body — "
                       "backend parity requires draws sliced from "
                       "pre-split round keys; new split sites fork the "
                       "streams")
                sev = "warn"
            findings.append(Finding(
                mod.path, node.lineno, "rekey-in-round", sev, msg,
                snippet=mod.snippet(node.lineno)))
    return findings


# ---------------------------------------------------------------------------
# zero-tail-restore


def _check_zero_tail(mod: _Mod) -> List[Finding]:
    if not mod.path.endswith(_ZERO_TAIL_SUFFIXES):
        return []
    findings = []
    for fn in mod.tree.body:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        calls: Set[str] = set()
        names: Set[str] = set()
        first_recv: Optional[ast.Call] = None
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                cname = _call_name(node.func)
                if cname:
                    calls.add(cname)
                    if cname in _RECEIVE_FNS and first_recv is None:
                        first_recv = node
                names.update(kw.arg for kw in node.keywords if kw.arg)
            elif isinstance(node, ast.Name):
                names.add(node.id)
            elif isinstance(node, ast.Attribute):
                names.add(node.attr)
            elif isinstance(node, ast.arg):
                names.add(node.arg)
        if (first_recv is not None and "zero_fold" in names
                and "restore_zero_tail" not in calls
                and not _waived(mod, first_recv, "zero-tail-restore")):
            findings.append(Finding(
                mod.path, first_recv.lineno, "zero-tail-restore", "error",
                f"{fn.name} receives a quantized aggregate with "
                "zero_fold reachable but never calls restore_zero_tail "
                "— sign-wire padding blocks dequantize to ±scale, not "
                "zero", snippet=mod.snippet(first_recv.lineno)))
    return findings


# ---------------------------------------------------------------------------
# kernel-mirror


def _contains_pallas_call(fn: ast.AST) -> bool:
    return any(_call_name(getattr(n, "func", None)) == "pallas_call"
               for n in ast.walk(fn) if isinstance(n, ast.Call))


def _param_names(fn) -> Tuple[List[str], List[str]]:
    """(positional names, all names) — posonly + args + kwonly."""
    a = fn.args
    pos = [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
    return pos, pos + [p.arg for p in a.kwonlyargs]


def _check_kernel_mirror(kernel_mods: Sequence[_Mod],
                         ref_mod: Optional[_Mod]) -> List[Finding]:
    findings = []
    if ref_mod is None:
        for mod in kernel_mods:
            for fn in mod.tree.body:
                if (isinstance(fn, ast.FunctionDef)
                        and not fn.name.startswith("_")
                        and _contains_pallas_call(fn)):
                    findings.append(Finding(
                        mod.path, fn.lineno, "kernel-mirror", "error",
                        f"public Pallas kernel {fn.name} but "
                        "repro/kernels/ref.py is absent — no oracle to "
                        "mirror it", snippet=mod.snippet(fn.lineno)))
        return findings

    ref_fns = {fn.name: fn for fn in ref_mod.tree.body
               if isinstance(fn, ast.FunctionDef)}
    for mod in kernel_mods:
        for fn in mod.tree.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            if fn.name.startswith("_") or not _contains_pallas_call(fn):
                continue
            if _waived(mod, fn, "kernel-mirror"):
                continue
            stem = fn.name[:-5] if fn.name.endswith("_slab") else fn.name
            ref_name = f"{stem}_ref"
            snip = mod.snippet(fn.lineno)
            rfn = ref_fns.get(ref_name)
            if rfn is None:
                findings.append(Finding(
                    mod.path, fn.lineno, "kernel-mirror", "error",
                    f"public Pallas kernel {fn.name} has no oracle "
                    f"{ref_name} in {ref_mod.path} — the parity suite "
                    "cannot cover it", snippet=snip))
                continue
            kpos, kall = _param_names(fn)
            rpos, rall = _param_names(rfn)
            kset = set(kall) - KERNEL_ONLY_PARAMS
            rset = set(rall) - KERNEL_ONLY_PARAMS
            missing = sorted(kset - rset)
            extra = sorted(rset - kset)
            kp = [p for p in kpos if p not in KERNEL_ONLY_PARAMS]
            if missing or extra:
                parts = []
                if missing:
                    parts.append(f"oracle is missing {missing}")
                if extra:
                    parts.append(f"oracle has extra {extra}")
                findings.append(Finding(
                    mod.path, fn.lineno, "kernel-mirror", "error",
                    f"{fn.name} and {ref_name} signatures disagree: "
                    + "; ".join(parts), snippet=snip))
            elif rpos[:len(kp)] != kp:
                findings.append(Finding(
                    mod.path, fn.lineno, "kernel-mirror", "error",
                    f"{fn.name} positional operands {kp} but {ref_name} "
                    f"leads with {rpos[:len(kp)]}", snippet=snip))
    return findings


# ---------------------------------------------------------------------------
# local-import


def _is_import_guard(node: ast.AST) -> bool:
    if isinstance(node, ast.Try):
        for handler in node.handlers:
            types = handler.type
            if types is None:
                return True
            names = ([_call_name(e) for e in types.elts]
                     if isinstance(types, ast.Tuple)
                     else [_call_name(types)])
            if {"ImportError", "ModuleNotFoundError",
                    "Exception"} & set(filter(None, names)):
                return True
    if isinstance(node, ast.If):
        test = _dotted(node.test)
        if test and test.endswith("TYPE_CHECKING"):
            return True
    return False


def _check_local_imports(mod: _Mod) -> List[Finding]:
    findings = []

    def visit(node: ast.AST, in_fn: bool, guarded: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.Import, ast.ImportFrom)):
                if (in_fn and not guarded
                        and not _waived(mod, child, "local-import")):
                    if isinstance(child, ast.ImportFrom):
                        what = f"from {child.module or '.'} import ..."
                    else:
                        what = ("import "
                                + ", ".join(a.name for a in child.names))
                    findings.append(Finding(
                        mod.path, child.lineno, "local-import", "warn",
                        f"function-local `{what}` — hoist to module "
                        "level, or waive with `# repro-lint: "
                        "lazy-import (reason)` if it breaks a cycle or "
                        "defers a side effect",
                        snippet=mod.snippet(child.lineno)))
            visit(child,
                  in_fn or isinstance(child, (ast.FunctionDef,
                                              ast.AsyncFunctionDef,
                                              ast.Lambda)),
                  guarded or _is_import_guard(child))

    visit(mod.tree, False, False)
    return findings


# ---------------------------------------------------------------------------
# drivers


def analyze_sources(sources: Dict[str, str], *,
                    registry: Optional[Dict[str, int]] = None,
                    min_separator: int = MIN_SEPARATOR,
                    registry_path: str =
                    "src/repro/analysis/fold_registry.py",
                    check_registry_coverage: bool = False
                    ) -> List[Finding]:
    """Run every AST rule over ``{repo-relative path: source text}``."""
    if registry is None:
        registry = REGISTERED_FOLDS
    mods: List[_Mod] = []
    findings: List[Finding] = []
    for path in sorted(sources):
        npath = _norm(path)
        try:
            mods.append(_Mod(npath, sources[path]))
        except SyntaxError as exc:
            findings.append(Finding(
                npath, exc.lineno or 1, "syntax-error", "error",
                f"does not parse: {exc.msg}"))
    findings += _check_folds(mods, registry, min_separator,
                             registry_path, check_registry_coverage)
    for mod in mods:
        findings += _check_rekey(mod)
        findings += _check_zero_tail(mod)
        findings += _check_local_imports(mod)
    kernel_mods = [m for m in mods if _is_kernel_mod(m.path)]
    ref_mod = next((m for m in mods
                    if m.path.endswith("repro/kernels/ref.py")), None)
    findings += _check_kernel_mirror(kernel_mods, ref_mod)
    return sorted(findings)


def analyze_paths(paths: Iterable[Path], root: Path,
                  **kwargs) -> List[Finding]:
    """Analyze an explicit file set; paths reported relative to root."""
    root = Path(root).resolve()
    sources = {}
    for p in paths:
        p = Path(p).resolve()
        try:
            rel = p.relative_to(root)
        except ValueError:
            rel = p
        sources[_norm(rel)] = p.read_text()
    return analyze_sources(sources, **kwargs)


def analyze_repo(root: Path, **kwargs) -> List[Finding]:
    """Analyze every ``*.py`` under ``<root>/src``."""
    src = Path(root) / "src"
    kwargs.setdefault("check_registry_coverage", True)
    return analyze_paths(sorted(src.rglob("*.py")), Path(root), **kwargs)
