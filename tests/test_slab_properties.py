"""Property-based pytree <-> slab contract tests.

test_slab.py pins hand-picked shapes; this module generates random
pytrees — mixed dtypes (incl. bf16/f16), empty (size-0) leaves,
non-lane sizes, deep nesting — and asserts the three slab invariants on
every draw:

  1. round-trip identity: slab_to_tree(tree_to_slab(t)) == t (bitwise —
     every supported dtype embeds exactly in f32),
  2. zero tail: slab[spec.total:] == 0, for every shard-aligned padding,
  3. norm equality: ||slab||_2 == sqrt(sum_leaf ||leaf||_2^2).

Strategies draw only scalars (a structure seed + knobs) and the tree is
built deterministically from them with ``random.Random`` — this keeps
the tests meaningful under both real hypothesis and the deterministic
stub in tests/_hypothesis_stub.py.
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.slab import (LANE, make_slab_spec, slab_to_tree,
                             stack_to_slab, tree_to_slab)

_DTYPES = (jnp.float32, jnp.bfloat16, jnp.float16)
_DIMS = (0, 1, 2, 3, 5, 7, 33, 128, 130)


def _random_leaf(rnd: random.Random):
    ndim = rnd.randint(0, 3)
    shape = tuple(rnd.choice(_DIMS) for _ in range(ndim))
    dt = rnd.choice(_DTYPES)
    n = int(np.prod(shape, dtype=np.int64))
    vals = np.asarray([rnd.gauss(0.0, 3.0) for _ in range(n)], np.float32)
    return jnp.asarray(vals.reshape(shape), dt)


def _random_tree(rnd: random.Random, depth: int):
    """Random nested dict/list/tuple structure with >= 1 leaf."""
    if depth == 0 or rnd.random() < 0.35:
        return _random_leaf(rnd)
    kind = rnd.choice(("dict", "list", "tuple"))
    n = rnd.randint(1, 3)
    children = [_random_tree(rnd, depth - 1) for _ in range(n)]
    if kind == "dict":
        return {f"k{i}": c for i, c in enumerate(children)}
    return children if kind == "list" else tuple(children)


def _leaf_pairs(a, b):
    assert jax.tree.structure(a) == jax.tree.structure(b)
    return zip(jax.tree.leaves(a), jax.tree.leaves(b))


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(0, 4),
       shards=st.sampled_from([1, 2, 4, 8]))
def test_roundtrip_zero_tail_and_dtypes(seed, depth, shards):
    tree = _random_tree(random.Random(seed), depth)
    spec = make_slab_spec(tree, shards=shards)
    slab = tree_to_slab(spec, tree)
    # shard-aligned padding rule
    assert slab.shape == (spec.padded,)
    assert spec.padded % (LANE * shards) == 0
    assert spec.shards == shards and spec.padded == spec.shard_len * shards
    # zero tail (padding is a fixed point of every kernel mode)
    if spec.padded > spec.total:
        np.testing.assert_array_equal(np.asarray(slab[spec.total:]), 0.0)
    # bitwise round-trip, original shapes and dtypes
    back = slab_to_tree(spec, slab)
    for orig, rec in _leaf_pairs(tree, back):
        assert orig.shape == rec.shape and orig.dtype == rec.dtype
        np.testing.assert_array_equal(np.asarray(orig, np.float32),
                                      np.asarray(rec, np.float32))


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**31 - 1), depth=st.integers(0, 3))
def test_norm_equality(seed, depth):
    tree = _random_tree(random.Random(seed), depth)
    spec = make_slab_spec(tree)
    slab = tree_to_slab(spec, tree)
    # f64 accumulation on both sides isolates the property under test
    # (the zero tail adds nothing) from f32 summation-order noise.
    tree_sq = sum(float(np.sum(np.square(np.asarray(l, np.float64))))
                  for l in jax.tree.leaves(tree))
    slab_sq = float(np.sum(np.square(np.asarray(slab, np.float64))))
    np.testing.assert_allclose(slab_sq, tree_sq, rtol=1e-9, atol=1e-9)


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**31 - 1), shards=st.sampled_from([2, 4, 8]))
def test_shard_padding_preserves_real_entries(seed, shards):
    """Specs built with different ``shards`` values must agree on every
    real slab entry — only the zero tail grows (the per-shard PRNG
    contract of repro.core.shard depends on this)."""
    tree = _random_tree(random.Random(seed), 3)
    spec1 = make_slab_spec(tree)
    specp = make_slab_spec(tree, shards=shards)
    assert spec1.total == specp.total
    assert specp.padded >= spec1.padded
    s1 = np.asarray(tree_to_slab(spec1, tree))
    sp = np.asarray(tree_to_slab(specp, tree))
    np.testing.assert_array_equal(s1[:spec1.total], sp[:spec1.total])
    # the bigger padding round-trips identically
    for orig, rec in _leaf_pairs(tree, slab_to_tree(specp,
                                                    jnp.asarray(sp))):
        np.testing.assert_array_equal(np.asarray(orig, np.float32),
                                      np.asarray(rec, np.float32))


@settings(deadline=None, max_examples=15)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5))
def test_stacked_rows_match_per_client_slabs(seed, n):
    rnd = random.Random(seed)
    base = _random_tree(rnd, 2)
    spec = make_slab_spec(base)
    stacked_tree = jax.tree.map(
        lambda l: jnp.stack([l] * n) * jnp.arange(
            1.0, n + 1.0, dtype=jnp.float32).reshape((n,) + (1,) * l.ndim
                                                     ).astype(l.dtype),
        base)
    stacked = stack_to_slab(spec, stacked_tree)
    assert stacked.shape == (n, spec.padded)
    for c in range(n):
        per_client = tree_to_slab(
            spec, jax.tree.map(lambda l: l[c], stacked_tree))
        np.testing.assert_array_equal(np.asarray(stacked[c]),
                                      np.asarray(per_client))


def test_all_empty_leaves_roundtrip():
    """Size-0 leaves are legal; an all-empty tree makes a length-0 slab."""
    tree = {"a": jnp.zeros((0,), jnp.float32),
            "b": jnp.zeros((3, 0), jnp.bfloat16)}
    spec = make_slab_spec(tree)
    assert spec.total == 0 and spec.padded == 0
    back = slab_to_tree(spec, tree_to_slab(spec, tree))
    for orig, rec in _leaf_pairs(tree, back):
        assert orig.shape == rec.shape and orig.dtype == rec.dtype


def test_bad_shards_rejected():
    import pytest
    with pytest.raises(ValueError):
        make_slab_spec({"w": jnp.ones(4)}, shards=0)
