from repro.data.partition import dirichlet_partition, heterogeneity_index, iid_partition
from repro.data.pipeline import FederatedBatcher
from repro.data.synthetic import (ClassificationData, gaussian_mixture,
                                  lm_batches, synthetic_images, token_stream)

__all__ = ["dirichlet_partition", "heterogeneity_index", "iid_partition",
           "FederatedBatcher", "ClassificationData", "gaussian_mixture",
           "lm_batches", "synthetic_images", "token_stream"]
