"""Paper-style experiment driver: reproduce the Fig. 2 comparison and the
alpha sweep (Fig. 5) on the CPU-sized synthetic stand-ins, printing the
orderings the paper claims, plus the Remark-3 scenario the paper only
gestures at: the server does NOT know the channel's tail index. The
mismatch section runs AdaGrad-OTA with the optimizer's assumed alpha
decoupled from the true channel alpha (the ``launch.train
--alpha / --alpha-opt`` split) and with the closed estimation loop
(``--track-alpha`` / ``alpha="auto"``).

    PYTHONPATH=src python examples/paper_experiment.py [--rounds 80]
        [--skip-mismatch]
"""

import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_figs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--skip-mismatch", action="store_true",
                    help="skip the Remark-3 alpha-mismatch / online-"
                         "tracking section")
    args = ap.parse_args()
    paper_figs.ROUNDS = args.rounds

    print("=== Fig.2: ADOTA vs FedAvgM (logreg / EMNIST-like, Dir=0.1, a=1.5)")
    recs = paper_figs.fig2()
    for r in recs:
        print(f"  {r['optimizer']:12s} loss {r['final_loss']:.4f} "
              f"acc {r['accuracy']:.4f}")
    by = {r["optimizer"]: r for r in recs}
    assert by["adam_ota"]["accuracy"] >= by["fedavgm"]["accuracy"], \
        "paper claim violated: Adam-OTA should beat FedAvgM"

    print("=== Fig.5: tail-index sweep (AdaGrad-OTA)")
    recs = paper_figs.fig5()
    for r in recs:
        print(f"  alpha={r['alpha']:.1f} loss {r['final_loss']:.4f}")
    losses = [r["final_loss"] for r in recs]
    print("  (expected: loss decreases as alpha rises)",
          "OK" if losses[0] >= losses[-1] else "VIOLATED")

    if not args.skip_mismatch:
        import alpha_mismatch
        print("=== Remark 3: unknown alpha — mismatch vs online tracking "
              f"(true alpha={alpha_mismatch.TRUE_ALPHA})")
        loss_m, _, _ = alpha_mismatch.train(alpha_mismatch.TRUE_ALPHA,
                                            args.rounds)
        loss_g, _, _ = alpha_mismatch.train(2.0, args.rounds)
        loss_t, _, a_hat = alpha_mismatch.train("auto", args.rounds)
        print(f"  (expected: tracked ~ matched < gaussian-assumed; "
              f"alpha_hat -> {alpha_mismatch.TRUE_ALPHA})",
              "OK" if loss_t <= loss_g and
              abs(a_hat - alpha_mismatch.TRUE_ALPHA) < 0.15 else "VIOLATED")


if __name__ == "__main__":
    main()
