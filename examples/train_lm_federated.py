"""End-to-end driver: federated training of a language model with the
OTA channel + ADOTA server, via the production launcher.

Default is a CPU-friendly reduced model; pass --preset 100m for the
~100M-parameter run (a few hundred rounds; minutes-to-hours on CPU,
seconds on a real pod).

    PYTHONPATH=src python examples/train_lm_federated.py -- \
        --preset tiny --rounds 60 --clients 8
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    if "--" in sys.argv:
        sys.argv = [sys.argv[0]] + sys.argv[sys.argv.index("--") + 1:]
    elif len(sys.argv) == 1:
        sys.argv += ["--preset", "tiny", "--rounds", "60", "--clients", "8",
                     "--seq", "64"]
    main()
