"""Slab-resident training state: the model + optimizer state as slabs.

The ADOTA server update (Eqs. 8-11) is a pure *slab* computation —
Delta/nu/w are flat vectors updated once per round — so the multi-round
loop should never leave slab form. ``SlabTrainState`` is that resident
state: the parameter slab, the optimizer-state slabs (in
``state_slab_rows`` order) and the round counter, with the static
``SlabSpec`` riding along as pytree aux data (so jit caches on the
layout, and every function taking a state knows its slab geometry
without a side channel).

Pytrees are materialised only at the *boundaries* of training:

* **init** — ``init_train_state`` / ``pack_train_state`` flatten the
  freshly initialised params (and, for pack, an existing
  ``ServerOptState``) into slabs once;
* **eval / metrics / checkpoint** — ``unpack_train_state`` restores
  ``(params, ServerOptState)`` exactly as the per-round pytree API
  would have produced them (params in their original leaf dtypes,
  state in f32, placeholder leaves for modes that carry no
  delta/nu), so evaluation code and the npz checkpointer are agnostic
  to which loop produced the state.

Inside the loop (``repro.core.fl.make_slab_round_step``,
``repro.core.shard.make_shard_slab_step``) the state stays a slab; under
a mesh each device keeps only its ``spec.shard_len`` slice of every slab
(true ZeRO: optimizer state never moves between devices).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptive import (AdaptiveConfig, ServerOptState,
                                 pack_state_slabs, state_slab_rows)
from repro.core.slab import (SlabSpec, make_slab_spec, slab_to_tree,
                             tree_to_slab, zeros_slab)

PyTree = Any


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SlabTrainState:
    """Resident training state of the slab engine.

    ``w`` is the (spec.padded,) f32 parameter slab; ``opt`` the
    optimizer-state slabs in ``state_slab_rows(cfg)`` order (same
    layout/padding as ``w``); ``step`` the int32 round counter. Under
    the sharded engine the arrays are the SAME global shapes but live
    sharded over the mesh's client axes (each device holds one
    ``spec.shard_len`` slice); the pytree structure is identical either
    way, so checkpoints and boundary conversions are mesh-agnostic.

    ``alpha_hat`` is the resident tail-index telemetry (PR 5): the f32
    scalar EMA of the per-round log-moment estimate the kernel
    epilogues reduce, carried across rounds (scan carry, checkpointed,
    replicated under a mesh). 0.0 is the "not yet seeded" sentinel —
    alpha lives in (1, 2] — and is what non-tracking configs keep, so
    the pytree structure is uniform whether or not the estimator runs.
    It is the first state field that feeds *telemetry* back into the
    update rule (``AdaptiveConfig.alpha == "auto"``).

    ``ef`` is the per-transmitter error-feedback residual (PR 7,
    ``UplinkConfig.error_feedback``): a (spec.shards, spec.padded) f32
    array — one FULL-WIDTH row per transmitter, because each
    transmitter quantizes its whole faded partial sum before the MAC
    collective slices it. Under a mesh it is sharded over the client
    axes on dim 0 (each device carries its own (1, padded) residual,
    like its fading draw); single-device engines have shards == 1.
    ``None`` when error feedback is off — EF-on and EF-off states are
    deliberately different pytree structures, so the f32/no-EF paths
    stay bitwise and checkpoints without the slab load as None.

    ``spec`` is static aux data: two states with different layouts are
    different pytree types to jit, and it never becomes a traced value.
    """

    step: jax.Array
    w: jax.Array
    opt: Tuple[jax.Array, ...]
    alpha_hat: jax.Array
    spec: SlabSpec
    ef: Any = None

    def tree_flatten(self):
        return ((self.step, self.w, self.opt, self.alpha_hat, self.ef),
                self.spec)

    @classmethod
    def tree_unflatten(cls, spec, children):
        step, w, opt, alpha_hat, ef = children
        return cls(step=step, w=w, opt=tuple(opt), alpha_hat=alpha_hat,
                   spec=spec, ef=ef)


def init_train_state(cfg: AdaptiveConfig, params: PyTree,
                     spec: SlabSpec | None = None,
                     shards: int = 1,
                     error_feedback: bool = False) -> SlabTrainState:
    """Fresh resident state: params packed once, optimizer slabs zero.

    Matches ``make_server_optimizer(cfg).init`` for every registered
    optimizer (all init their delta/nu trees to zeros). Pass ``spec``
    to reuse a prebuilt layout, or ``shards`` to build one with the
    shard-aligned padding rule. ``alpha_hat`` starts at the unseeded
    sentinel 0.0 (the first tracked round adopts its raw estimate).
    ``error_feedback=True`` allocates the zeroed (spec.shards,
    spec.padded) per-transmitter residual rows (a fresh EF loop starts
    with nothing carried)."""
    if spec is None:
        spec = make_slab_spec(params, shards=shards)
    n_rows = len(state_slab_rows(cfg))
    ef = (jnp.zeros((spec.shards, spec.padded), jnp.float32)
          if error_feedback else None)
    return SlabTrainState(step=jnp.zeros((), jnp.int32),
                          w=tree_to_slab(spec, params),
                          opt=tuple(zeros_slab(spec) for _ in range(n_rows)),
                          alpha_hat=jnp.zeros((), jnp.float32),
                          spec=spec, ef=ef)


def pack_train_state(cfg: AdaptiveConfig, spec: SlabSpec, params: PyTree,
                     state: ServerOptState,
                     alpha_hat: jax.Array | None = None) -> SlabTrainState:
    """Boundary: flatten an existing ``(params, ServerOptState)`` pair.

    ``ServerOptState`` carries no tail-index telemetry (it predates the
    closed alpha loop), so ``alpha_hat`` defaults to the unseeded
    sentinel; pass an existing scalar to preserve it across a
    pack/unpack boundary."""
    if alpha_hat is None:
        alpha_hat = jnp.zeros((), jnp.float32)
    return SlabTrainState(step=jnp.asarray(state.step, jnp.int32),
                          w=tree_to_slab(spec, params),
                          opt=pack_state_slabs(cfg, spec, state),
                          alpha_hat=jnp.asarray(alpha_hat, jnp.float32),
                          spec=spec)


def unpack_train_state(cfg: AdaptiveConfig, state: SlabTrainState
                       ) -> Tuple[PyTree, ServerOptState]:
    """Boundary: materialise ``(params, ServerOptState)`` pytrees.

    Params come back in their original leaf dtypes, optimizer state in
    f32 (``cast=False``). Modes that carry no delta/nu slabs get the
    scalar-zero placeholders their ``init`` uses, so the result is
    structurally identical to what the per-round pytree API returns.
    """
    spec = state.spec
    rows = dict(zip(state_slab_rows(cfg), state.opt))
    zero = jnp.zeros((), jnp.float32)
    delta = (slab_to_tree(spec, rows["delta"], cast=False)
             if "delta" in rows else zero)
    if "vmax" in rows:
        nu = {"v": slab_to_tree(spec, rows["nu"], cast=False),
              "vmax": slab_to_tree(spec, rows["vmax"], cast=False)}
    elif "nu" in rows:
        nu = slab_to_tree(spec, rows["nu"], cast=False)
    else:
        nu = zero
    params = slab_to_tree(spec, state.w)
    return params, ServerOptState(step=state.step, delta=delta, nu=nu)


def spec_meta(spec: SlabSpec) -> dict:
    """JSON-serialisable fingerprint of a slab layout — stored beside
    checkpoints so resume can verify the current model produces the SAME
    layout (no silent re-packing drift when shapes/dtypes/shards change).
    """
    return {"total": spec.total, "padded": spec.padded,
            "shards": spec.shards,
            "shapes": [list(s) for s in spec.shapes],
            "dtypes": [str(d) for d in spec.dtypes],
            "offsets": list(spec.offsets),
            # The treedef catches drifts the leaf metadata cannot: two
            # same-shaped leaves renamed or reordered flatten to
            # identical shapes/dtypes/offsets but would silently swap
            # their slab segments on resume.
            "treedef": str(spec.treedef)}


def check_spec_meta(spec: SlabSpec, meta: dict, where: str = "") -> None:
    """Raise if ``spec`` does not reproduce the checkpointed layout."""
    current = spec_meta(spec)
    if current != meta:
        diff = [k for k in current if current[k] != meta.get(k)]
        raise ValueError(
            f"slab layout mismatch{' in ' + where if where else ''}: "
            f"checkpoint was written with a different {'/'.join(diff)} "
            f"(ckpt {[meta.get(k) for k in diff]!r} vs current "
            f"{[current[k] for k in diff]!r}); resuming would re-pack "
            "state into a different layout")
