"""Qwen2.5-14B [hf:Qwen/Qwen2.5-14B family, dims per assignment]: 48L,
d_model 5120, 40 heads (GQA kv=8), d_ff 13824, vocab 152064; QKV bias,
RMSNorm + SwiGLU, rope_theta 1e6. Full attention (long_500k served via
the beyond-paper sliding-window variant applied at launch)."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab=152064, qkv_bias=True, rope_theta=1000000.0,
    notes="GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B card family]",
)
