"""Statistical tests for the online tail-index estimators (Remark 3).

The log-moment estimator is the one the adaptive optimizer would
consume, so it gets tight recovery bounds across the whole alpha grid in
(1, 2]; the Hill estimator is a cross-check that is only asymptotically
unbiased for stable laws (the stable tail is Pareto only in the limit),
so agreement is asserted where its bias is small (alpha <= 1.3) and its
growing bias toward the Gaussian endpoint is itself pinned as expected
behavior. Tolerances are calibrated for n = 200k samples: the
log-moment error there is ~0.01, tested at 0.05.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import OTAChannelConfig, sample_alpha_stable
from repro.core.tail_index import (alpha_from_log_moments,
                                   estimate_from_gradient_residual,
                                   hill_estimate, log_moment_estimate,
                                   log_moment_stats, update_alpha_ema)

N_SAMPLES = 200_000
ALPHA_GRID = (1.2, 1.5, 1.8, 2.0)


def _draw(alpha, seed=0, scale=0.7, n=N_SAMPLES):
    return sample_alpha_stable(jax.random.key(seed), alpha, (n,), scale)


@pytest.mark.parametrize("alpha", ALPHA_GRID)
def test_log_moment_recovers_alpha(alpha):
    a_hat, scale_hat = log_moment_estimate(_draw(alpha))
    assert abs(float(a_hat) - alpha) < 0.05
    np.testing.assert_allclose(float(scale_hat), 0.7, rtol=0.05)


@pytest.mark.parametrize("alpha", (1.1, 1.2, 1.3))
def test_hill_recovers_heavy_tails(alpha):
    """Hill is near-unbiased only deep in the heavy-tail regime."""
    a_hat = hill_estimate(_draw(alpha))
    assert abs(float(a_hat) - alpha) < 0.2


@pytest.mark.parametrize("alpha", (1.1, 1.2, 1.3))
def test_estimators_agree_in_heavy_tail_regime(alpha):
    x = _draw(alpha, seed=1)
    a_lm, _ = log_moment_estimate(x)
    a_h = hill_estimate(x)
    assert abs(float(a_lm) - float(a_h)) < 0.15


def test_hill_bias_grows_toward_gaussian():
    """Known limitation, pinned: by alpha = 1.8 the Hill estimate
    overshoots substantially (the stable tail is no longer Pareto at
    reachable order statistics) — which is why the optimizer consumes
    the log-moment estimate, not Hill."""
    a_h = hill_estimate(_draw(1.8))
    assert float(a_h) - 1.8 > 0.3


def test_gaussian_endpoint_clips_to_two():
    """alpha == 2 is exactly Gaussian; the estimator must saturate its
    upper clip instead of wandering above 2."""
    a_hat, _ = log_moment_estimate(_draw(2.0))
    assert float(a_hat) == 2.0
    # plain normal draws (the alpha=2 stable with scale 1/sqrt(2))
    g = jax.random.normal(jax.random.key(3), (N_SAMPLES,))
    a_g, _ = log_moment_estimate(g)
    assert float(a_g) >= 1.95


def test_clip_bounds_are_hard():
    # var(log|x|) -> huge: alpha pegs at the lower clip
    spread = jnp.asarray([1e-30, 1e30] * 64, jnp.float32)
    a_lo, _ = log_moment_estimate(spread)
    assert float(a_lo) == pytest.approx(1.01)
    # var(log|x|) -> 0: alpha pegs at the upper clip
    const = jnp.full((256,), 3.0, jnp.float32)
    a_hi, _ = log_moment_estimate(const)
    assert float(a_hi) == 2.0


def test_residual_estimation_recovers_channel_alpha():
    """Differencing a clean reference gradient against the OTA one
    recovers the interference tail index (the deployment path)."""
    cfg = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    g_clean = jax.random.normal(jax.random.key(4), (N_SAMPLES,)) * 0.0
    xi = sample_alpha_stable(jax.random.key(5), cfg.alpha, (N_SAMPLES,),
                             cfg.xi_scale)
    a_hat, scale_hat = estimate_from_gradient_residual(g_clean, g_clean + xi)
    assert abs(float(a_hat) - cfg.alpha) < 0.05
    np.testing.assert_allclose(float(scale_hat), cfg.xi_scale, rtol=0.05)


@pytest.mark.parametrize("n", [1, 6, 8, 9])
def test_hill_small_samples_do_not_raise(n):
    """Regression: k = max(8, k_frac*n) used to exceed top_k's window
    for n < 9 (ValueError at n=6); k is now clamped to n-1 and the
    degenerate cases return finite clipped values."""
    a = hill_estimate(jnp.ones((n,), jnp.float32))
    assert np.isfinite(float(a)) and 0.5 <= float(a) <= 4.0
    # all-equal samples: zero log-spacing denominator -> the upper clip
    if n >= 2:
        assert float(a) == 4.0
    b = hill_estimate(sample_alpha_stable(jax.random.key(8), 1.5, (n,)))
    assert np.isfinite(float(b)) and 0.5 <= float(b) <= 4.0


def test_hill_all_equal_large_sample_clips():
    """The zero-denominator guard is independent of the n >= 9 fix."""
    a = hill_estimate(jnp.full((1000,), 2.5, jnp.float32))
    assert float(a) == 4.0


@pytest.mark.parametrize("alpha", ALPHA_GRID)
def test_alpha_from_log_moments_matches_sample_estimator(alpha):
    """The sufficient-statistics form (what the fused kernel epilogues
    feed) reproduces the raw-sample log-moment estimate."""
    x = _draw(alpha, seed=2)
    a_raw, c_raw = log_moment_estimate(x)
    a_st, c_st = alpha_from_log_moments(log_moment_stats(x))
    np.testing.assert_allclose(float(a_st), float(a_raw), atol=2e-3)
    np.testing.assert_allclose(float(c_st), float(c_raw), rtol=2e-3)


def test_log_moment_stats_are_additive():
    """Stats from disjoint slices ADD to the full-vector stats — the
    contract that lets shard slices psum their 3-vectors."""
    x = _draw(1.5, seed=3, n=4096)
    whole = log_moment_stats(x)
    parts = log_moment_stats(x[:1000]) + log_moment_stats(x[1000:])
    np.testing.assert_allclose(np.asarray(parts), np.asarray(whole),
                               rtol=1e-5)
    # zeros (the slab padding tail) contribute nothing
    padded = log_moment_stats(jnp.concatenate([x, jnp.zeros(512)]))
    np.testing.assert_allclose(np.asarray(padded), np.asarray(whole),
                               rtol=1e-6)


def test_update_alpha_ema_seeding_and_gating():
    stats = log_moment_stats(_draw(1.5, seed=4, n=65536))
    est, _ = alpha_from_log_moments(stats)
    # unseeded (sentinel 0): adopts the raw estimate
    first = update_alpha_ema(jnp.zeros(()), stats, rho=0.1)
    np.testing.assert_allclose(float(first), float(est), rtol=1e-6)
    # seeded: blends with weight rho
    second = update_alpha_ema(jnp.asarray(2.0), stats, rho=0.1)
    np.testing.assert_allclose(float(second), 0.9 * 2.0 + 0.1 * float(est),
                               rtol=1e-6)
    # no residual observed (count 0): previous value passes through
    empty = log_moment_stats(jnp.zeros((128,)))
    assert float(empty[0]) == 0.0
    held = update_alpha_ema(jnp.asarray(1.7), empty, rho=0.1)
    assert float(held) == pytest.approx(1.7)
    # ... including the unseeded sentinel itself
    assert float(update_alpha_ema(jnp.zeros(()), empty, rho=0.1)) == 0.0


def test_estimators_are_jittable():
    x = _draw(1.5, seed=6, n=4096)
    a_jit, _ = jax.jit(log_moment_estimate)(x)
    a_ref, _ = log_moment_estimate(x)
    np.testing.assert_allclose(float(a_jit), float(a_ref), rtol=1e-6)
    h_jit = jax.jit(hill_estimate)(x)
    np.testing.assert_allclose(float(h_jit), float(hill_estimate(x)),
                               rtol=1e-6)
