"""Multi-head Latent Attention (MLA) — MiniCPM3 / DeepSeek-V2 family.

Queries and keys/values are produced through low-rank latents; the decode
KV cache stores ONLY the compressed latent ``c_kv`` (kv_lora_rank) plus
the shared rotary key ``k_pe`` (qk_rope_head_dim) per token — the point
of MLA. Decode uses the *absorbed* formulation: the up-projections
``W_uk`` / ``W_uv`` are folded into the query / output sides, so scores
and weighted sums are computed directly in latent space.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64
    rope_theta: float = 10000.0

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


def mla_init(key, cfg: MLAConfig, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(k1, cfg.d_model, (cfg.q_lora_rank,), dtype),
        "q_norm": rmsnorm_init(cfg.q_lora_rank),
        "wq_b": dense_init(k2, cfg.q_lora_rank,
                           (cfg.n_heads, cfg.qk_head_dim), dtype),
        "wkv_a": dense_init(k3, cfg.d_model,
                            (cfg.kv_lora_rank + cfg.qk_rope_head_dim,), dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank),
        "wkv_b": dense_init(k4, cfg.kv_lora_rank,
                            (cfg.n_heads, cfg.qk_nope_head_dim + cfg.v_head_dim),
                            dtype),
        "wo": dense_init(k5, cfg.n_heads * cfg.v_head_dim, (cfg.d_model,), dtype),
    }


def _queries(p, cfg: MLAConfig, x, positions):
    q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_pe = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_pe                         # (B,S,H,nope), (B,S,H,rope)


def _latents(p, cfg: MLAConfig, x, positions):
    kv = dense(p["wkv_a"], x)
    c_kv = rmsnorm(p["kv_norm"], kv[..., :cfg.kv_lora_rank])
    k_pe = apply_rope(kv[..., None, cfg.kv_lora_rank:], positions,
                      cfg.rope_theta)[:, :, 0]  # (B,S,rope) shared across heads
    return c_kv, k_pe


def mla_self_attention(p: dict, cfg: MLAConfig, x: jax.Array,
                       positions: jax.Array) -> jax.Array:
    """Training/prefill path: decompress K/V and run standard causal MHA."""
    b, s, _ = x.shape
    q_nope, q_pe = _queries(p, cfg, x, positions)
    c_kv, k_pe = _latents(p, cfg, x, positions)
    kv = dense(p["wkv_b"], c_kv)                       # (B,S,H,nope+v)
    k_nope = kv[..., :cfg.qk_nope_head_dim]
    v = kv[..., cfg.qk_nope_head_dim:]
    scale = 1.0 / math.sqrt(cfg.qk_head_dim)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope.astype(jnp.float32),
                         k_nope.astype(jnp.float32))
              + jnp.einsum("bqhr,bkr->bhqk", q_pe.astype(jnp.float32),
                           k_pe.astype(jnp.float32))) * scale
    pos = positions if positions.ndim == 2 else positions[None, :]
    causal = (pos[:, None, :, None] >= pos[:, None, None, :])
    scores = jnp.where(causal, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return dense(p["wo"], o.reshape(b, s, -1).astype(x.dtype))


def init_mla_cache(batch: int, length: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, length, cfg.qk_rope_head_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }


def mla_prefill(p: dict, cfg: MLAConfig, x: jax.Array, positions: jax.Array,
                length: int) -> Tuple[jax.Array, dict]:
    y = mla_self_attention(p, cfg, x, positions)
    c_kv, k_pe = _latents(p, cfg, x, positions)
    s = x.shape[1]
    cache = init_mla_cache(x.shape[0], length, cfg, dtype=c_kv.dtype)
    cache["c_kv"] = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, 0, 0))
    cache["k_pe"] = jax.lax.dynamic_update_slice(cache["k_pe"], k_pe, (0, 0, 0))
    cache["pos"] = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.arange(s, dtype=jnp.int32), (0,))
    return y, cache


def mla_decode_step(p: dict, cfg: MLAConfig, x: jax.Array, cache: dict,
                    pos: jax.Array) -> Tuple[jax.Array, dict]:
    """Absorbed one-token decode against the latent cache.

    score_h(s) = q_nope_h^T W_uk_h c_s + q_pe_h^T k_pe_s
    out_h      = (sum_s p_s c_s)^T W_uv_h
    """
    b = x.shape[0]
    posv = jnp.full((1,), pos)
    q_nope, q_pe = _queries(p, cfg, x, posv)            # (B,1,H,*)
    c_new, k_pe_new = _latents(p, cfg, x, posv)
    ck = jax.lax.dynamic_update_slice(cache["c_kv"],
                                      c_new.astype(cache["c_kv"].dtype),
                                      (0, pos, 0))
    kp = jax.lax.dynamic_update_slice(cache["k_pe"],
                                      k_pe_new.astype(cache["k_pe"].dtype),
                                      (0, pos, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"],
                                        jnp.full((1,), pos, jnp.int32), (pos,))
    w_uk = p["wkv_b"]["kernel"][..., :cfg.qk_nope_head_dim]   # (r,H,nope)
    w_uv = p["wkv_b"]["kernel"][..., cfg.qk_nope_head_dim:]   # (r,H,v)
    # Absorb W_uk into the query: (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(cfg.qk_head_dim)
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ck.astype(jnp.float32))
              + jnp.einsum("bqhr,bsr->bhqs", q_pe.astype(jnp.float32),
                           kp.astype(jnp.float32))) * scale
    valid = (cpos >= 0) & (cpos <= pos)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ck.astype(jnp.float32))
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_uv.astype(jnp.float32))
    y = dense(p["wo"], o.reshape(b, 1, -1).astype(x.dtype))
    return y, {"c_kv": ck, "k_pe": kp, "pos": cpos}
