"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort-based
dispatch (no (T, E, C) one-hots — scatter/gather through an (E, C, d)
buffer), batched expert SwiGLU, Switch-style load-balance auxiliary loss,
optional always-on shared expert (Kimi-K2 style).

Sharding intent: the expert dimension E of all expert weights and of the
dispatch buffer is sharded over the "model" mesh axis (expert
parallelism); tokens arrive sharded over "data". The token->expert
scatter is the all-to-all boundary — GSPMD inserts the collective from
the sharding constraints (baseline), and §Perf iterates on it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.layers import dense_init, swiglu, swiglu_init

# Expert-parallel shard_map context, installed by the launcher (the model
# code itself stays mesh-agnostic). When set AND cfg.sharded, moe_apply
# dispatches tokens locally on each (data, model) shard and psums the
# partial outputs over the model axis — replacing GSPMD's conservative
# (replicating) partition of the scatter/gather dispatch.
_SHARD_CTX = {"mesh": None, "data_axes": None, "model_axis": None}


def set_moe_sharding(mesh, data_axes, model_axis="model") -> None:
    _SHARD_CTX.update(mesh=mesh, data_axes=tuple(data_axes),
                      model_axis=model_axis)


def clear_moe_sharding() -> None:
    _SHARD_CTX.update(mesh=None, data_axes=None, model_axis=None)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    n_shared_experts: int = 0      # always-on experts (Kimi-K2 has 1)
    capacity_factor: float = 1.25
    normalize_topk: bool = True    # renormalise the k gates to sum to 1
    aux_loss_weight: float = 0.01
    sharded: bool = False          # use the shard_map expert-parallel path


def moe_init(key, cfg: MoEConfig, dtype=jnp.bfloat16) -> dict:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(kr, d, (e,), jnp.float32),
        "gate": (jax.random.truncated_normal(kg, -2, 2, (e, d, f), jnp.float32)
                 * std).astype(dtype),
        "up": (jax.random.truncated_normal(ku, -2, 2, (e, d, f), jnp.float32)
               * std).astype(dtype),
        "down": (jax.random.truncated_normal(kd, -2, 2, (e, f, d), jnp.float32)
                 * (1.0 / math.sqrt(f))).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(ks, d, cfg.d_ff * cfg.n_shared_experts, dtype)
    return p


def _positions_in_expert(expert_ids: jax.Array, n_experts: int) -> jax.Array:
    """For each flat slot, its arrival rank within its expert (sort-based,
    O(n log n) and O(n) memory — no (n, E) one-hot)."""
    n = expert_ids.shape[0]
    order = jnp.argsort(expert_ids, stable=True)
    sorted_e = expert_ids[order]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, idx, 0))
    pos_sorted = idx - seg_start
    inv = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    return inv


def moe_apply(p: dict, cfg: MoEConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    mesh = _SHARD_CTX["mesh"]
    if (cfg.sharded and mesh is not None
            and cfg.n_experts % mesh.shape[_SHARD_CTX["model_axis"]] == 0
            and x.shape[0] % __import__("math").prod(
                mesh.shape[a] for a in _SHARD_CTX["data_axes"]) == 0):
        return _moe_apply_sharded(p, cfg, x, mesh,
                                  _SHARD_CTX["data_axes"],
                                  _SHARD_CTX["model_axis"])
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)      # (T, k)
    if cfg.normalize_topk:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    k = cfg.top_k
    e_flat = expert_idx.reshape(t * k).astype(jnp.int32)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pos = _positions_in_expert(e_flat, cfg.n_experts)
    capacity = max(1, int(t * k * cfg.capacity_factor / cfg.n_experts))
    keep = pos < capacity
    pos_c = jnp.minimum(pos, capacity - 1)

    # Dispatch: (E, C, d) buffer; dropped slots contribute zero.
    buf = jnp.zeros((cfg.n_experts, capacity, d), x.dtype)
    vals = xt[tok_flat] * keep[:, None].astype(x.dtype)
    buf = buf.at[e_flat, pos_c].add(vals)

    # Expert SwiGLU, batched over E.
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"],
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, p["up"],
                   preferred_element_type=jnp.float32)
    h = (g * u).astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"],
                         preferred_element_type=jnp.float32)     # (E, C, d) f32

    # Combine: gather each slot's result, weight by its gate, sum over k.
    slot_out = out_buf[e_flat, pos_c] * keep[:, None]            # (T*k, d) f32
    w = gate_vals.reshape(t * k, 1)
    y = jnp.zeros((t, d), jnp.float32).at[tok_flat].add(slot_out * w)
    y = y.astype(x.dtype).reshape(b, s, d)

    if cfg.n_shared_experts:
        y = y + swiglu(p["shared"], x)

    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    f_e = jnp.zeros((cfg.n_experts,), jnp.float32).at[e_flat].add(
        keep.astype(jnp.float32)) / jnp.maximum(t * k, 1)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_weight * cfg.n_experts * jnp.sum(f_e * p_e)
    return y, aux


def moe_reference_dense(p: dict, cfg: MoEConfig, x: jax.Array) -> jax.Array:
    """Oracle: route every token through ALL experts densely and mix by the
    (renormalised) top-k gates. O(E/k) more FLOPs; used only in tests to
    validate the dispatch path (capacity_factor must be large enough that
    nothing is dropped)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_topk:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    full_gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], expert_idx].set(gate_vals)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["gate"],
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("td,edf->tef", xt, p["up"],
                   preferred_element_type=jnp.float32)
    h = (g * u).astype(x.dtype)
    o = jnp.einsum("tef,efd->ted", h, p["down"],
                   preferred_element_type=jnp.float32)
    y = jnp.einsum("ted,te->td", o, full_gates).astype(x.dtype)
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        y = y + swiglu(p["shared"], x)
    return y


# ---------------------------------------------------------------------------
# Explicit expert-parallel path (shard_map).
# ---------------------------------------------------------------------------

def _dispatch_local(cfg: MoEConfig, router_k, gate_w, up_w, down_w, xl,
                    model_axis: str):
    """Per-shard MoE: tokens local to this data shard, experts local to
    this model shard; contributions from remote experts arrive via the
    psum over the model axis (token activations are replicated there)."""
    b, s, d = xl.shape
    t = b * s
    xt = xl.reshape(t, d)
    e_loc = gate_w.shape[0]
    j = jax.lax.axis_index(model_axis)
    base = j * e_loc

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_k.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_topk:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    k = cfg.top_k
    e_flat = expert_idx.reshape(t * k).astype(jnp.int32)
    tok_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    owned = (e_flat >= base) & (e_flat < base + e_loc)
    # local expert id; non-owned slots go to a dump row e_loc.
    e_local = jnp.where(owned, e_flat - base, e_loc)
    pos = _positions_in_expert(e_local, e_loc + 1)
    capacity = max(1, int(t * k * cfg.capacity_factor / cfg.n_experts))
    keep = owned & (pos < capacity)
    pos_c = jnp.minimum(pos, capacity - 1)
    e_c = jnp.minimum(e_local, e_loc - 1)

    buf = jnp.zeros((e_loc, capacity, d), xl.dtype)
    vals = xt[tok_flat] * keep[:, None].astype(xl.dtype)
    buf = buf.at[e_c, pos_c].add(vals)

    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w,
                               preferred_element_type=jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", buf, up_w,
                   preferred_element_type=jnp.float32)
    h = (g * u).astype(xl.dtype)
    # Keep the combine-side gather in bf16: halves the largest HBM stream
    # of the layer (T*k x d slot gather); the per-token sum over k and the
    # cross-shard psum still accumulate in f32.
    out_buf = jnp.einsum("ecf,efd->ecd", h, down_w,
                         preferred_element_type=jnp.float32).astype(xl.dtype)

    slot_out = out_buf[e_c, pos_c] * keep[:, None].astype(xl.dtype)
    w = gate_vals.reshape(t * k, 1)
    y = jnp.zeros((t, d), jnp.float32).at[tok_flat].add(
        slot_out.astype(jnp.float32) * w)
    y = jax.lax.psum(y, model_axis)
    y = y.astype(xl.dtype).reshape(b, s, d)

    f_e_local = jnp.zeros((cfg.n_experts,), jnp.float32).at[e_flat].add(
        keep.astype(jnp.float32)) / jnp.maximum(t * k, 1)
    f_e = jax.lax.psum(f_e_local, model_axis)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_weight * cfg.n_experts * jnp.sum(f_e * p_e)
    return y, aux


def _moe_apply_sharded(p: dict, cfg: MoEConfig, x: jax.Array, mesh,
                       data_axes, model_axis) -> Tuple[jax.Array, jax.Array]:
    dp = tuple(data_axes)

    def body(router_k, gate_w, up_w, down_w, xl):
        y, aux = _dispatch_local(cfg, router_k, gate_w, up_w, down_w, xl,
                                 model_axis)
        aux = jax.lax.pmean(aux, dp)
        return y, aux

    y, aux = shard_map(
        body, mesh,
        (P(None, None), P(model_axis, None, None),
         P(model_axis, None, None), P(model_axis, None, None),
         P(dp, None, None)),
        (P(dp, None, None), P()),
    )(p["router"]["kernel"], p["gate"], p["up"], p["down"], x)
    if cfg.n_shared_experts:
        y = y + swiglu(p["shared"], x)
    return y, aux
