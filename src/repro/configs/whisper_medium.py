"""Whisper-medium [arXiv:2212.04356]: enc-dec, 24+24L, d_model 1024,
16 heads (MHA kv=16), d_ff 4096, vocab 51865; LayerNorm + GeLU, learned
decoder positions. The mel-spectrogram + conv frontend is a STUB — the
input spec supplies precomputed frame embeddings (B, 1500, 1024)."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, norm="layernorm", mlp="gelu", qkv_bias=True,
    enc_seq=1500,
    notes="enc-dec, conv frontend stubbed [arXiv:2212.04356]",
)
