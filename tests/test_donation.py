"""Donated resident slabs (PR 8).

``make_slab_round_runner(donate=True)`` donates the incoming
``SlabTrainState`` buffers into the compiled scan chunk, so the
executable aliases every state slab to its output instead of holding a
second resident copy. Contracts:

* ``donation_report`` proves the aliasing from the compiled
  executable itself (memory analysis + the HLO ``input_output_alias``
  table): every donated state byte is aliased, none copied;
* the donated runner computes the SAME trajectory as the undonated one
  (donation is an allocation contract, not a numeric change) — and the
  donated input is genuinely consumed (jax raises on reuse);
* ``run_rounds_slab`` threads state linearly, so a donated runner
  drives it end to end;
* ``donate=True`` without ``jit`` is rejected (there is nothing to
  donate into).

Backends whose compiled memory analysis does not expose alias sizes
report ``supported=False`` and the assertions skip (not fail).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, donation_report, init_train_state,
                        make_slab_round_runner, run_rounds_slab)

N = 4
ROUNDS = 3


def _case(uplink="f32", ef=False):
    params = {"w": jax.random.normal(jax.random.key(0), (300,)),
              "b": jax.random.normal(jax.random.key(1), (7,))}

    def loss_fn(p, batch):
        return sum(jnp.mean((x - t) ** 2)
                   for x, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(batch)))

    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (N,) + p.shape),
        params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          uplink=UplinkConfig(mode=uplink,
                                              error_feedback=ef))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=N)
    return params, loss_fn, batches, ch, ad, fl


def _example_args(ad, params, batches, ef=False):
    st = init_train_state(ad, params, error_feedback=ef)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(7), t)
                      for t in range(ROUNDS)])
    stacked = jax.tree.map(lambda b: jnp.stack([b] * ROUNDS), batches)
    return st, keys, stacked


@pytest.mark.parametrize("uplink,ef", [("f32", False), ("sign", True)])
def test_donated_slabs_fully_aliased(uplink, ef):
    """Every byte of the donated state — params, opt slabs, alpha_hat,
    and (when on) the EF slab — is aliased input->output by the
    compiled executable: the resident update is in-place, no 2x state
    copy."""
    params, loss_fn, batches, ch, ad, fl = _case(uplink, ef)
    run = make_slab_round_runner(loss_fn, ch, ad, fl, donate=True)
    st, keys, stacked = _example_args(ad, params, batches, ef)
    rep = donation_report(run, st, keys, stacked)
    if not rep["supported"]:
        pytest.skip("compiled memory analysis does not expose aliasing "
                    "on this backend")
    assert rep["donated_bytes"] > 0
    assert rep["aliased_bytes"] == rep["donated_bytes"]
    n_leaves = len(jax.tree.leaves(st))
    assert rep["aliased_pairs"] is not None
    assert len(rep["aliased_pairs"]) == n_leaves


def test_donated_trajectory_matches_and_consumes():
    params, loss_fn, batches, ch, ad, fl = _case()
    run_plain = make_slab_round_runner(loss_fn, ch, ad, fl)
    run_don = make_slab_round_runner(loss_fn, ch, ad, fl, donate=True)
    st_a, keys, stacked = _example_args(ad, params, batches)
    st_b, _, _ = _example_args(ad, params, batches)
    out_a, ms_a = run_plain(st_a, keys, stacked)
    out_b, ms_b = run_don(st_b, keys, stacked)
    for a, b in zip(jax.tree.leaves(out_a), jax.tree.leaves(out_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ms_a.loss),
                                  np.asarray(ms_b.loss))
    # the donated argument is consumed — reuse must raise, a buffer
    # that silently survived would mean no aliasing happened
    deleted = [x for x in jax.tree.leaves(st_b)
               if isinstance(x, jax.Array) and x.is_deleted()]
    if deleted:
        with pytest.raises(RuntimeError):
            _ = np.asarray(deleted[0])
    else:
        pytest.skip("backend did not consume donated buffers")


def test_run_rounds_slab_threads_donated_state():
    """The driver threads state linearly (each chunk's output is the
    next chunk's input), so a donating runner drives it end to end."""
    params, loss_fn, batches, ch, ad, fl = _case()
    run = make_slab_round_runner(loss_fn, ch, ad, fl, donate=True)
    st = init_train_state(ad, params)
    final, history = run_rounds_slab(
        run, st, jax.random.key(9), lambda t, k: batches, 6, chunk=2)
    assert len(history) == 6
    assert np.isfinite(history[-1]["loss"])
    assert int(final.step) == 6


def test_donate_requires_jit():
    params, loss_fn, batches, ch, ad, fl = _case()
    with pytest.raises(ValueError, match="jit"):
        make_slab_round_runner(loss_fn, ch, ad, fl, jit=False, donate=True)
