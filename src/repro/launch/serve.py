"""Batched serving driver: prefill a batch of prompts, then decode
autoregressively with the per-family cache (ring KV / MLA latent /
recurrent state).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b \
        --preset tiny --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.launch.train import preset_config
from repro.models.model import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-14b")
    ap.add_argument("--preset", choices=["tiny", "100m", "full"],
                    default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))
    b, s = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                          cfg.vocab)}
    if cfg.family == "encdec":
        batch["audio_embed"] = jax.random.normal(
            jax.random.key(2), (b, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            jax.random.key(2), (b, cfg.n_img_tokens, cfg.d_model),
            jnp.bfloat16)

    total = s + args.gen + cfg.n_meta_tokens
    length = min(total, cfg.window) if cfg.window else total

    t0 = time.time()
    logits, cache = model.prefill(params, batch, length=length)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(s + i))
        if args.temperature > 0:
            key = jax.random.fold_in(jax.random.key(args.seed + 2), i)
            tok = jax.random.categorical(
                key, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.arch} prefill {s} toks x{b}: {t_prefill*1e3:.1f} ms; "
          f"decode {args.gen} toks: {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.gen-1,1)*1e3:.2f} ms/tok)")
    print("generated ids[0,:16]:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
