"""Alpha mismatch: what tracking the tail index online buys (PR 5).

The ADOTA stepsize divides by the nu-accumulator's alpha-ROOT, so the
optimizer's assumed tail index shapes every update (and the convergence
rate itself, Theorem 1: O(ln T / T^{1-1/alpha})). Yang et al. show
mis-modeling the interference law degrades A-OTA training; this
experiment measures that mismatch and its online correction on a
heavy-tailed channel (true alpha = 1.2):

* ``matched``    — the server magically knows alpha = 1.2;
* ``mismatched`` — the server assumes Gaussian interference (alpha = 2,
  what you would assume with no tail knowledge);
* ``tracked``    — ``alpha = "auto"``: the closed loop estimates alpha
  from the log-moment pilot statistics the OTA kernel epilogue reduces
  every round, EMA-resident in the slab state, fed back into the fused
  update as a traced scalar.

Expected: ``tracked`` converges to the matched trajectory (alpha_hat
within ~0.05 of 1.2 after 80 rounds) with no oracle knowledge, while
the Gaussian assumption trails on final loss.

    PYTHONPATH=src python examples/alpha_mismatch.py [--rounds 80]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_train_state, make_slab_round_runner,
                        run_rounds_slab, unpack_train_state)
from repro.data import FederatedBatcher, gaussian_mixture
from repro.models.vision import accuracy, logistic_regression

TRUE_ALPHA = 1.2


def train(alpha_opt, rounds: int):
    n_clients = 20
    data = gaussian_mixture(4000, 32, 10, seed=0)
    model = logistic_regression(32, 10)
    batcher = FederatedBatcher(data, n_clients, 16, dir_alpha=0.1)

    channel = OTAChannelConfig(alpha=TRUE_ALPHA, xi_scale=0.3)
    server = AdaptiveConfig(optimizer="adagrad_ota", lr=0.05,
                            alpha=alpha_opt, beta2=0.3)
    run = make_slab_round_runner(model.loss_fn, channel, server,
                                 FLConfig(n_clients=n_clients),
                                 backend="pallas")

    def batch_fn(t, key):
        b = batcher(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    state = init_train_state(server, model.init(jax.random.key(0)))
    state, hist = run_rounds_slab(run, state, jax.random.key(1), batch_fn,
                                  rounds, chunk=8)
    params, _ = unpack_train_state(server, state)
    acc = accuracy(model, params, jnp.asarray(data.x), data.y)
    name = "auto" if alpha_opt == "auto" else f"{alpha_opt:.1f}"
    print(f"  alpha_opt={name:5s} final loss {hist[-1]['loss']:.4f}  "
          f"acc {acc:.4f}  alpha_hat {hist[-1]['alpha_hat']:.4f}")
    return hist[-1]["loss"], acc, hist[-1]["alpha_hat"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=80)
    args = ap.parse_args()

    print(f"== AdaGrad-OTA on a true-alpha={TRUE_ALPHA} channel ==")
    loss_m, acc_m, _ = train(TRUE_ALPHA, args.rounds)      # matched oracle
    loss_g, acc_g, _ = train(2.0, args.rounds)             # Gaussian guess
    loss_t, acc_t, a_hat = train("auto", args.rounds)      # closed loop

    err = abs(a_hat - TRUE_ALPHA)
    print(f"\ntracked alpha_hat = {a_hat:.4f} (true {TRUE_ALPHA}, "
          f"err {err:.4f})")
    print(f"loss: matched {loss_m:.4f} | tracked {loss_t:.4f} | "
          f"gaussian-assumed {loss_g:.4f}")
    recovered = abs(loss_t - loss_m) <= max(
        0.5 * abs(loss_g - loss_m), 0.02)
    print("tracking recovers the matched trajectory:",
          "OK" if recovered else "VIOLATED")


if __name__ == "__main__":
    main()
