"""Hymba-1.5B [arXiv:2411.13676]: 32L, d_model 1600, parallel hybrid
heads — 25 attention heads (GQA kv=5, sliding window 1024) alongside a
Mamba SSM branch (state 16) in every layer — plus 128 learnable meta
tokens; d_ff 5504, vocab 32001. SSM state makes long_500k native."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, window=1024, ssm_state=16, ssm_expand=2,
    n_meta_tokens=128,
    notes="parallel attn+mamba heads [arXiv:2411.13676]",
)
