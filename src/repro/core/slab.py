"""Pytree <-> slab bridge: the canonical flat representation of a model.

The ADOTA round (Eqs. 7-11) is elementwise over every parameter, so the
fused Pallas kernels (``repro.kernels.adaptive_update``,
``repro.kernels.ota_channel``) operate on one contiguous 1-D f32 buffer
— a *slab* — instead of a pytree of leaves. This module owns the
contract between the two worlds:

* ``make_slab_spec(tree)`` records, **statically**, each leaf's shape,
  dtype, flat size and offset into the slab, plus the lane-padded total
  (``LANE == 128`` to line up with the TPU VPU lanes the kernels tile
  over). Shapes are static under jit, so the spec can be built inside a
  traced function at no runtime cost. ``shards=P`` rounds the padded
  length up to a multiple of ``lane * P`` — the *shard-aligned padding
  rule* of the sharded slab engine: the slab then splits into P
  contiguous, equal, lane-aligned slices, one per device of the mesh's
  client axes, and every slice is itself a valid kernel operand. The
  extra padding is zeros, so specs built with different ``shards`` agree
  on every real entry and round-trip identically.
* ``tree_to_slab(spec, tree)`` flattens every leaf, casts to f32 (the
  canonical compute dtype of the server update — the jnp reference path
  also computes in f32), concatenates in leaf order and zero-pads to the
  lane multiple. Zero padding is load-bearing: it keeps L2 norms exact
  and is a fixed point of every update mode (the kernels never leak
  padding into real entries).
* ``slab_to_tree(spec, slab)`` inverts it, slicing at the recorded
  offsets, restoring shapes and (optionally) the original leaf dtypes —
  matching the jnp path's ``.astype(w.dtype)`` on the way out.
* ``stack_to_slab(spec, tree)`` is the client-stacked variant: leaves of
  shape ``(N, *leaf_shape)`` become one ``(N, padded)`` matrix so the
  whole OTA MAC is a single ``ota_channel_slab`` launch.

Adding a new fused optimizer mode does not touch this file: the slab
layout is mode-independent; only ``repro.kernels.adaptive_update`` (the
kernel math) and ``repro.core.adaptive`` (the mode dispatch) change.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128   # must match repro.kernels.*.LANE (TPU vector lane width)


@dataclasses.dataclass(frozen=True)
class SlabSpec:
    """Static layout of a pytree inside a 1-D slab.

    ``offsets[i]:offsets[i]+sizes[i]`` is leaf i (in ``treedef`` order);
    ``total`` is the exact element count and ``padded`` the lane-rounded
    slab length actually materialised.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    total: int
    padded: int
    shards: int = 1

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)

    @property
    def shard_len(self) -> int:
        """Length of one per-device slab slice (``padded / shards``)."""
        return self.padded // self.shards


def make_slab_spec(tree: PyTree, lane: int = LANE, shards: int = 1) -> SlabSpec:
    """Build the static slab layout of ``tree`` (arrays or ShapeDtypeStructs).

    ``shards`` > 1 applies the shard-aligned padding rule: the padded
    length becomes the smallest multiple of ``lane * shards`` holding all
    leaves, so the slab splits into ``shards`` equal lane-aligned slices.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot build a slab spec from an empty pytree")
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets, off = [], 0
    for s in sizes:
        offsets.append(off)
        off += s
    quantum = lane * shards
    padded = -(-off // quantum) * quantum
    return SlabSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=tuple(offsets), sizes=tuple(sizes), total=off,
                    padded=padded, shards=shards)


def tree_to_slab(spec: SlabSpec, tree: PyTree,
                 dtype=jnp.float32) -> jax.Array:
    """Flatten ``tree`` into one (padded,) slab of ``dtype`` (zero tail)."""
    leaves = spec.treedef.flatten_up_to(tree)
    flat = jnp.concatenate([jnp.asarray(l).reshape(-1).astype(dtype)
                            for l in leaves])
    return jnp.pad(flat, (0, spec.padded - spec.total))


def slab_to_tree(spec: SlabSpec, slab: jax.Array, cast: bool = True) -> PyTree:
    """Invert ``tree_to_slab``: restore shapes and (if ``cast``) dtypes.

    ``cast=False`` keeps the slab dtype on every leaf — used for the f32
    optimizer state, whose leaves mirror the parameter shapes but stay
    float32 regardless of the parameter dtype.
    """
    leaves = []
    for shape, dt, off, size in zip(spec.shapes, spec.dtypes, spec.offsets,
                                    spec.sizes):
        leaf = jax.lax.dynamic_slice_in_dim(slab, off, size).reshape(shape)
        leaves.append(leaf.astype(dt) if cast else leaf)
    return jax.tree.unflatten(spec.treedef, leaves)


def stack_to_slab(spec: SlabSpec, tree: PyTree,
                  dtype=jnp.float32) -> jax.Array:
    """Flatten a client-stacked tree (leaves ``(N, *shape)``) to (N, padded)."""
    leaves = spec.treedef.flatten_up_to(tree)
    n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [jnp.asarray(l).reshape(n, -1).astype(dtype) for l in leaves], axis=1)
    return jnp.pad(flat, ((0, 0), (0, spec.padded - spec.total)))


def zeros_slab(spec: SlabSpec, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((spec.padded,), dtype)
