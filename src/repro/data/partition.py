"""Non-iid client partitioning (paper Sec. VI-A).

The paper partitions CIFAR/EMNIST across clients with a symmetric
Dirichlet distribution over label proportions, concentration ``Dir``
(default 0.1; smaller = more heterogeneous). ``dirichlet_partition``
reproduces that exactly: for each class, a Dirichlet(Dir) draw over the
N clients splits that class's examples.
"""

from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, dir_alpha: float,
                        seed: int = 0, min_per_client: int = 1
                        ) -> List[np.ndarray]:
    """Return per-client index arrays partitioning ``labels``.

    Retries until every client has at least ``min_per_client`` examples
    (standard practice; Dir=0.1 frequently starves clients otherwise).
    """
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    for _ in range(100):
        idx_by_client = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet([dir_alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for client, part in enumerate(np.split(idx_c, cuts)):
                idx_by_client[client].append(part)
        parts = [np.concatenate(p) for p in idx_by_client]
        if min(len(p) for p in parts) >= min_per_client:
            for p in parts:
                rng.shuffle(p)
            return parts
    raise RuntimeError("dirichlet_partition: could not satisfy min_per_client")


def iid_partition(n_examples: int, n_clients: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n_examples)
    return list(np.array_split(idx, n_clients))


def heterogeneity_index(parts: List[np.ndarray], labels: np.ndarray) -> float:
    """Mean TV distance between per-client label dists and the global one
    (0 = iid). Used by tests to assert Dir ordering."""
    n_classes = int(labels.max()) + 1
    global_p = np.bincount(labels, minlength=n_classes) / len(labels)
    tv = []
    for p in parts:
        cp = np.bincount(labels[p], minlength=n_classes) / max(len(p), 1)
        tv.append(0.5 * np.abs(cp - global_p).sum())
    return float(np.mean(tv))
