"""Pytree checkpointing (npz-based, dependency-free).

Saves/restores {params, server optimizer state, round counter, rng key}
so long federated runs resume exactly. Leaves are flattened to
path-keyed arrays in one compressed .npz; pytree structure is rebuilt
from the stored key paths on load (against a template tree).
"""

from __future__ import annotations

import os
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "|"
_BF16 = "~bf16"   # npz cannot store ml_dtypes.bfloat16; stored as uint16 view


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        ks = []
        for p in path:
            ks.append(str(getattr(p, "key", getattr(p, "idx", p))))
        arr = np.asarray(leaf)
        key = _SEP.join(ks)
        if arr.dtype == jnp.bfloat16:
            out[key + _BF16] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def save(path: str, tree: PyTree) -> None:
    """Atomic save: write to a temp file in the same dir, then rename."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load(path: str, template: PyTree) -> PyTree:
    """Restore into the structure (and dtypes) of ``template``."""
    with np.load(path) as data:
        stored = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in paths:
        ks = []
        for p in path_keys:
            ks.append(str(getattr(p, "key", getattr(p, "idx", p))))
        key = _SEP.join(ks)
        if key + _BF16 in stored:
            arr = jnp.asarray(stored[key + _BF16].view(jnp.bfloat16))
        elif key in stored:
            arr = stored[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if hasattr(leaf, "dtype"):
            arr = jnp.asarray(arr, dtype=leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def latest_round(ckpt_dir: str, prefix: str = "round_") -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    rounds = []
    for f in os.listdir(ckpt_dir):
        if f.startswith(prefix) and f.endswith(".npz"):
            try:
                rounds.append((int(f[len(prefix):-4]), f))
            except ValueError:
                continue
    if not rounds:
        return None
    return os.path.join(ckpt_dir, max(rounds)[1])
