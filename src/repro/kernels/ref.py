"""Pure-jnp oracles for every Pallas kernel (the ``ref`` side of the
kernel allclose tests, and the fallback path on non-TPU backends)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import CMS_E_FLOOR, CMS_U_BOUND, cms_transform
from repro.core.tail_index import log_moment_stats
from repro.kernels.ota_channel import unpack_sign_slab


def adaptive_update_ref(g: jax.Array, delta, nu, w: jax.Array, *, lr: float,
                        beta1: float, beta2: float, alpha, eps: float,
                        mode: str, nu_max=None) -> Tuple[jax.Array, ...]:
    """One fused server update on a flat parameter slab (paper Eq. 8-11).

    mode: "adagrad" -> v += |Delta|^a ; "adam" -> v = b2 v + (1-b2)|Delta|^a ;
    "amsgrad" -> adam v plus non-decreasing vmax denominator ; "yogi" ->
    sign-controlled additive v ; "momentum" -> FedAvgM (Delta = b1 Delta + g,
    no v; beta1 is the momentum coefficient) ; "sgd" -> plain FedAvg.
    All state in f32; w keeps its dtype. ``alpha`` may be a python float
    or a traced f32 scalar (the closed-loop tracked tail index) — the
    elementwise math is identical either way. Returns the same
    ``(*updated_state, w')`` tuple as ``adaptive_update_slab``.
    """
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if mode == "sgd":
        return ((wf - lr * gf).astype(w.dtype),)
    gain = 1.0 if mode == "momentum" else (1.0 - beta1)
    delta = beta1 * delta + gain * gf
    if mode == "momentum":
        return delta, (wf - lr * delta).astype(w.dtype)
    ad = jnp.abs(delta)
    da = jnp.where(ad == 0.0, jnp.zeros_like(ad), ad ** alpha)
    if mode == "adagrad":
        nu = nu + da
    elif mode == "adam":
        nu = beta2 * nu + (1.0 - beta2) * da
    elif mode == "amsgrad":
        nu = beta2 * nu + (1.0 - beta2) * da
        nu_max = jnp.maximum(nu_max, nu)
    elif mode == "yogi":
        nu = nu - (1.0 - beta2) * jnp.sign(nu - da) * da
    else:
        raise ValueError(mode)
    denom_v = nu_max if mode == "amsgrad" else nu
    denom = jnp.maximum(denom_v + eps, 0.0) ** (1.0 / alpha)
    w_new = (wf - lr * delta / denom).astype(w.dtype)
    if mode == "amsgrad":
        return delta, nu, nu_max, w_new
    return delta, nu, w_new


def _residual_stats_ref(xi: jax.Array, scale: float) -> jax.Array:
    """Oracle of the pilot-statistics epilogue: ``[count, sum log|r|,
    sum log^2|r|]`` of the residual r = scale * xi over its nonzero
    entries (what ``ota_channel._residual_stats_row`` reduces per grid
    step). Delegates to the estimator's own reduction — the contract is
    exact agreement with what ``alpha_from_log_moments`` consumes, so
    there is deliberately only one jnp spelling of it."""
    return log_moment_stats(scale * xi)


def ota_channel_ref(grads: jax.Array, h: jax.Array, u: jax.Array,
                    e: jax.Array, *, alpha: float, scale: float,
                    n_total: Optional[int] = None,
                    pilot_stats: bool = False):
    """Fused OTA MAC on a slab: (1/N) sum_n h_n grads[n] + xi, where xi is
    the CMS transform of uniform angles u in (-pi/2, pi/2) and Exp(1)
    draws e (both shape (d,)). Same guards as
    ``repro.core.channel.cms_transform``: u clipped strictly inside
    (-pi/2, pi/2), e floored — finite everywhere incl. alpha == 2
    (Gaussian reduction).

    grads: (N, d); h: (N,). ``n_total`` overrides the 1/N normalisation
    (defaults to the local row count N), mirroring the kernel's
    global-count contract for sharded partial sums. Returns (d,)
    float32, plus the (3,) residual log-moment statistics when
    ``pilot_stats=True`` (the oracle of the kernel's fused epilogue).
    """
    # Guard constants shared with the production transform so the
    # oracle can't silently drift from it; the expression itself is
    # written out independently on purpose.
    n = grads.shape[0]
    if n_total is None:
        n_total = n
    agg = jnp.einsum("n,nd->d", h.astype(jnp.float32),
                     grads.astype(jnp.float32)) / n_total
    a = alpha
    u = jnp.clip(u, -CMS_U_BOUND, CMS_U_BOUND)
    e = jnp.maximum(e, CMS_E_FLOOR)
    xi = (jnp.sin(a * u) / jnp.cos(u) ** (1.0 / a)
          * (jnp.cos((1.0 - a) * u) / e) ** ((1.0 - a) / a))
    out = agg + scale * xi
    if pilot_stats:
        return out, _residual_stats_ref(xi, scale)
    return out


LANE = 128       # must match repro.kernels.ota_channel.LANE
INT8_MAX = 127.0


def ota_transmit_ref(grads: jax.Array, h: jax.Array, *,
                     n_total: Optional[int] = None, quantize: bool = False,
                     r: Optional[jax.Array] = None, stochastic: bool = True,
                     qmode: str = "int8", zero_fold: bool = False,
                     ef: Optional[jax.Array] = None,
                     return_residual: bool = False,
                     acc: Optional[jax.Array] = None,
                     row_chunk: Optional[int] = None):
    """Transmit-stage oracle: faded partial sum, optionally quantized
    (``qmode="int8"``: per-LANE-block max|x|/127 scales + stochastic
    rounding; ``qmode="sign"``: 1-bit signSGD, payload = sign(x) with
    blockwise mean|x| magnitudes, deterministic; ``zero_fold=True``
    selects the 1-bit-packable sign variant — q in {-1, +1}, exact
    zeros fold to +1, all-zero blocks scale 0).

    Mirrors ``ota_channel.ota_transmit_slab`` op for op. Note the
    agreement contract is *one quantization step*, not bitwise: the
    interpret-mode kernel reduces the faded sum in a (slightly)
    different f32 order, and a one-ulp difference there can flip an
    individual ``floor(x/s + r)`` rounding decision, which surfaces as
    a full quantum (one scale) on that entry. Hence the int8 parity
    tests assert per-entry error <= the entry's block scale (plus exact
    equality on the overwhelming majority), not allclose at f32
    rounding. (Sign payloads flip only where the partial sits within
    f32 rounding of 0 or of a block-mean boundary — same contract.)

    The same one-quantization-step contract covers the compiled
    in-kernel SR path (``sr_seed=`` on the kernel wrapper, no oracle
    equivalent here): its rounding uniforms come from the pltpu counter
    PRNG rather than this module's host-drawn threefry stream, so an
    individual entry's rounding decision may differ from the oracle's —
    but both are uniform on [0, 1), so every entry still lands within
    one block scale of ``x/s`` rounded either way, and both estimators
    are unbiased. Tests that pin trajectories bitwise must use the
    host-drawn path (the default everywhere interpret mode can run).

    ``ef`` (error feedback) is the (d,) carried residual added into the
    faded partial before quantization; ``return_residual=True`` appends
    the fresh residual ``x - dequant(quant(x))`` to the return.

    ``acc``/``row_chunk`` mirror the kernel's streamed client axis:
    start from the (d,) f32 carry (zeros if None) and fold the client
    rows in per ``row_chunk``-sized chunk, each chunk's faded partial
    divided by ``n_total`` as it lands. f32-only, like the kernel.

    grads: (N, d); h: (N,). Returns (d,) f32, or ``(payload int8 (d,),
    scales f32 (d // 128,)[, residual f32 (d,)])`` when
    ``quantize=True``.
    """
    n, d = grads.shape
    if n_total is None:
        n_total = n
    streamed = acc is not None or row_chunk is not None
    if streamed and quantize:
        raise ValueError("quantize=True cannot stream/accumulate "
                         "(acc=/row_chunk=); quantize the completed f32 "
                         "partial in a separate single-row call")
    h2 = h.reshape(n, 1).astype(jnp.float32)
    if streamed:
        rc = n if row_chunk is None else min(row_chunk, n)
        if rc < 1:
            raise ValueError(f"row_chunk must be >= 1, got {row_chunk}")
        gf = grads.astype(jnp.float32)
        agg = (jnp.zeros((d,), jnp.float32) if acc is None
               else acc.astype(jnp.float32))
        for s in range(0, n, rc):
            agg = agg + jnp.sum(h2[s:s + rc] * gf[s:s + rc],
                                axis=0) / n_total
        return agg
    agg = jnp.sum(h2 * grads.astype(jnp.float32), axis=0) / n_total
    if not quantize:
        return agg
    if d % LANE != 0:
        raise ValueError(f"quantized transmit needs d % {LANE} == 0, got {d}")
    if qmode not in ("int8", "sign"):
        raise ValueError(f'unknown qmode {qmode!r}; options: "int8", "sign"')
    if ef is not None:
        agg = agg + ef.astype(jnp.float32)
    a = agg.reshape(d // LANE, LANE)
    if zero_fold and qmode != "sign":
        raise ValueError("zero_fold is a sign-quantizer variant; "
                         f"qmode is {qmode!r}")
    if qmode == "sign":
        meanabs = jnp.mean(jnp.abs(a), axis=1, keepdims=True)
        if zero_fold:
            s = meanabs
            q = jnp.where(a < 0.0, -1, 1).astype(jnp.int8)
        else:
            s = jnp.where(meanabs > 0.0, meanabs, 1.0)
            q = jnp.sign(a).astype(jnp.int8)
    else:
        maxabs = jnp.max(jnp.abs(a), axis=1, keepdims=True)
        s = jnp.where(maxabs > 0.0, maxabs / INT8_MAX, 1.0)
        y = a / s
        if stochastic:
            y = jnp.floor(y + r.reshape(d // LANE, LANE))
        else:
            y = jnp.round(y)
        q = jnp.clip(y, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    ret = (q.reshape(-1), s.reshape(-1))
    if return_residual:
        resid = a - q.astype(jnp.float32) * s
        ret = ret + (resid.reshape(-1),)
    return ret


def ota_receive_ref(payload: jax.Array, scales: jax.Array, u: jax.Array,
                    e: jax.Array, *, alpha: float, scale: float,
                    packed: Optional[str] = None,
                    pilot_stats: bool = False):
    """Receive-stage oracle: dequantize + superpose R int8 payload rows,
    then add the CMS interference. Mirrors ``ota_channel.ota_receive_slab``
    (op-exact, see ``ota_transmit_ref`` for why). ``pilot_stats=True``
    also returns the (3,) residual log-moment statistics of the injected
    interference (the fused-epilogue oracle).

    payload: (R, d) int8; scales: (R, d // 128) f32; u, e: (d,).
    Returns (d,) f32, or ``(out, stats)``. ``packed="fold"|"planes"``
    accepts the bit-packed uint32 sign wire instead — the unpack is
    shared with the kernel wrapper (same words, same decode), so the
    oracle exercises the identical wire bits.
    """
    if packed is not None:
        payload = unpack_sign_slab(payload, scales.shape[1] * LANE,
                                   planes=(packed == "planes"))
    rows, d = payload.shape
    deq = (payload.astype(jnp.float32).reshape(rows, d // LANE, LANE)
           * scales[..., None])
    agg = jnp.sum(deq, axis=0).reshape(-1)
    xi = cms_transform(u, e, alpha)
    out = agg + scale * xi
    if pilot_stats:
        return out, _residual_stats_ref(xi, scale)
    return out


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Masked GQA attention oracle. q: (B,Sq,H,D); k,v: (B,Sk,K,D)."""
    b, sq, hn, d = q.shape
    kheads = k.shape[2]
    g = hn // kheads
    qg = q.reshape(b, sq, kheads, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    dpos = qpos[:, None] - kpos[None, :]
    ok = jnp.ones_like(dpos, bool)
    if causal:
        ok &= dpos >= 0
    if window is not None:
        ok &= dpos < window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hn, d).astype(q.dtype)
