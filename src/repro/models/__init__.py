from repro.models.model import Model, ModelConfig, build_model, partition_spec
