"""jax version compatibility shims.

The repo targets current jax (``jax.shard_map``, ``jax.sharding.AxisType``)
but must degrade gracefully on older releases (this CPU container ships
0.4.x, where shard_map still lives in ``jax.experimental`` and meshes
have no axis_types). Centralising the fallbacks here keeps version
probes out of model/launch/test code.
"""

from __future__ import annotations

import jax

try:
    from jax.sharding import AxisType  # jax >= 0.5
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def make_auto_mesh(shape, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if AxisType is None:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``check`` maps onto ``check_vma`` (new) / ``check_rep`` (old).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    # repro-lint: lazy-import (version fallback: jax.experimental.shard_map
    # only exists / is only wanted on old jax, probed at call time)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)
