"""Streamed client axis (PR 6): O(chunk) rounds, participation, weights.

The acceptance contracts:

* chunk >= N, full participation, no weights reproduces the resident
  slab round BITWISE on ``uplink="f32"`` (un-jitted — identical op
  sequence; under jit XLA may reassociate the client reduction between
  the two programs, so jitted trajectories are pinned at 1e-5 like
  every other cross-engine pair);
* the participation draw is ONE full (N,) uniform keyed off the round
  key via ``PART_FOLD`` — all backends sample literally identical
  clients, and ``sample_rate >= 1`` consumes no PRNG state at all;
* a zero-participation round is well-defined: the server update is
  SKIPPED (state bitwise unchanged, only the round counter advances)
  and the metrics record ``n_participants == 0``;
* uniform weights reduce to the unweighted path; non-uniform weights
  match the closed form sum(m w h g) / sum(m w);
* the accumulating / chunked transmit kernel agrees with its op-
  mirrored jnp oracle, and refuses the quantize epilogue (which must
  see the COMPLETED partial).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_train_state, make_round_step,
                        make_slab_round_runner, make_slab_round_step,
                        make_slab_spec, participation_mask,
                        round_participation, sample_fading,
                        streamed_round_parts)

N = 8
SHAPES = [(3, 45), (130,), (1,)]


def _params(key=None):
    ks = jax.random.split(key or jax.random.key(0), len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _batches(params, n=N, key=None):
    return jax.tree.map(
        lambda p: jax.random.normal(key or jax.random.key(3),
                                    (n,) + p.shape), params)


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _configs(uplink="f32", **fl_kw):
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          uplink=UplinkConfig(mode=uplink))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    return ch, ad, FLConfig(n_clients=fl_kw.pop("n_clients", N), **fl_kw)


def _trajectory(ch, ad, fl, backend, rounds=3, jit=True, params=None,
                batches=None):
    params = params or _params()
    batches = batches if batches is not None else _batches(params)
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend=backend,
                                jit=jit)
    st = init_train_state(ad, params)
    ms = None
    for t in range(rounds):
        st, ms = step(st, jax.random.fold_in(jax.random.key(7), t), batches)
    return st, ms


def _state_arrays(st):
    return [st.w, *st.opt, st.alpha_hat]


# ---------------------------------------------------------------------------
# Tentpole: streamed == resident
# ---------------------------------------------------------------------------

def test_chunk_ge_n_bitwise_f32_unjitted():
    """chunk >= N + full participation + no weights executes the exact
    resident slab op sequence: BITWISE equal trajectories, f32 uplink.
    (The jnp backend's resident path is the per-leaf pytree engine — a
    different op sequence — so it is covered by the 1e-5 tier below.)"""
    ch, ad, fl_res = _configs()
    _, _, fl_str = _configs(client_chunk=N)
    st_r, m_r = _trajectory(ch, ad, fl_res, "pallas", jit=False)
    st_s, m_s = _trajectory(ch, ad, fl_str, "pallas", jit=False)
    for a, b in zip(_state_arrays(st_r), _state_arrays(st_s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_s.n_participants) == float(N)
    np.testing.assert_allclose(float(m_r.loss), float(m_s.loss), rtol=1e-6)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_chunk_ge_n_jitted_close(backend):
    """Under jit XLA may reassociate the client reduction differently
    between the two programs — 1e-5, like every cross-engine pair."""
    ch, ad, fl_res = _configs()
    _, _, fl_str = _configs(client_chunk=N)
    st_r, _ = _trajectory(ch, ad, fl_res, backend, jit=True)
    st_s, _ = _trajectory(ch, ad, fl_str, backend, jit=True)
    for a, b in zip(_state_arrays(st_r), _state_arrays(st_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_chunk_ge_n_int8_close():
    """The quantized uplink composes with streaming: the completed
    partial crosses the same quantize + receive launches."""
    ch, ad, fl_res = _configs(uplink="int8")
    _, _, fl_str = _configs(uplink="int8", client_chunk=N)
    st_r, _ = _trajectory(ch, ad, fl_res, "pallas", jit=False)
    st_s, _ = _trajectory(ch, ad, fl_str, "pallas", jit=False)
    for a, b in zip(_state_arrays(st_r), _state_arrays(st_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("chunk", [2, 4])
def test_chunk_lt_n_close(backend, chunk):
    """Chunked accumulation only reorders the f32 client sum."""
    ch, ad, fl_res = _configs()
    _, _, fl_str = _configs(client_chunk=chunk)
    st_r, m_r = _trajectory(ch, ad, fl_res, backend)
    st_s, m_s = _trajectory(ch, ad, fl_str, backend)
    for a, b in zip(_state_arrays(st_r), _state_arrays(st_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(m_r.loss), float(m_s.loss), rtol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_ragged_chunk_matches_resident(backend):
    """A chunk that does NOT divide N is legal (PR 7): the final ragged
    chunk is padded with zero-gain rows, so the trajectory matches the
    resident round like any other chunking (f32 reassociation only) and
    the padded rows fold in exactly 0.0."""
    ch, ad, fl_res = _configs()
    _, _, fl_rag = _configs(client_chunk=3)        # ceil(8/3) = 3 chunks
    st_r, m_r = _trajectory(ch, ad, fl_res, backend)
    st_s, m_s = _trajectory(ch, ad, fl_rag, backend)
    for a, b in zip(_state_arrays(st_r), _state_arrays(st_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    assert float(m_s.n_participants) == float(m_r.n_participants)
    np.testing.assert_allclose(float(m_r.loss), float(m_s.loss), rtol=1e-5)


def test_ragged_chunk_matches_divisible_chunk():
    """chunk=3 and chunk=2 over N=8 accumulate the same partial: the
    zero-gain padding rows of the ragged tail contribute nothing."""
    ch, ad, _ = _configs()
    _, _, fl2 = _configs(client_chunk=2)
    _, _, fl3 = _configs(client_chunk=3)
    st_a, _ = _trajectory(ch, ad, fl2, "pallas")
    st_b, _ = _trajectory(ch, ad, fl3, "pallas")
    for a, b in zip(_state_arrays(st_a), _state_arrays(st_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_pytree_api_refuses_dynamic_rounds():
    ch, ad, fl = _configs(sample_rate=0.5)
    with pytest.raises(ValueError):
        make_round_step(_loss_fn, ch, ad, fl, backend="jnp")


# ---------------------------------------------------------------------------
# Partial participation
# ---------------------------------------------------------------------------

def test_participation_mask_contract():
    key = jax.random.key(5)
    ones = participation_mask(key, 16, 1.0)
    np.testing.assert_array_equal(np.asarray(ones), np.ones(16, np.float32))
    zeros = participation_mask(key, 16, 0.0)
    np.testing.assert_array_equal(np.asarray(zeros), np.zeros(16, np.float32))
    m = np.asarray(participation_mask(key, 4096, 0.25))
    assert set(np.unique(m)) <= {0.0, 1.0}
    assert 0.15 < m.mean() < 0.35
    # Deterministic in the key, and a different round key resamples.
    m2 = np.asarray(participation_mask(key, 4096, 0.25))
    np.testing.assert_array_equal(m, m2)
    m3 = np.asarray(participation_mask(jax.random.key(6), 4096, 0.25))
    assert not np.array_equal(m, m3)


def test_rate_one_consumes_no_prng_state():
    """Enabling the sampling code path at rate 1 must not perturb any
    other draw of the round: the mask comes from a PART_FOLD-separated
    key, and rate >= 1 short-circuits before even that."""
    key = jax.random.key(9)
    ch, _, fl = _configs(sample_rate=1.0)
    h_before = sample_fading(key, ch, (N,))
    mask, gain = round_participation(key, fl)
    h_after = sample_fading(key, ch, (N,))
    np.testing.assert_array_equal(np.asarray(h_before), np.asarray(h_after))
    np.testing.assert_array_equal(np.asarray(mask), np.ones(N, np.float32))
    np.testing.assert_array_equal(np.asarray(gain), np.asarray(mask))


@pytest.mark.parametrize("chunk", [None, 2])
def test_sampling_identical_across_backends(chunk):
    """jnp and pallas sample literally identical clients (one full draw
    keyed off the round key) and agree on the trajectory at 1e-5."""
    ch, ad, fl = _configs(sample_rate=0.5, client_chunk=chunk)
    st_j, m_j = _trajectory(ch, ad, fl, "jnp")
    st_p, m_p = _trajectory(ch, ad, fl, "pallas")
    assert float(m_j.n_participants) == float(m_p.n_participants)
    assert 0.0 < float(m_j.n_participants) < N
    for a, b in zip(_state_arrays(st_j), _state_arrays(st_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_sampling_identical_on_sharded_mesh():
    """The sharded engine slices the SAME full participation draw —
    mesh shape cannot change which clients transmit."""
    from repro.launch.mesh import make_client_mesh
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    ch, ad, fl = _configs(sample_rate=0.5, client_chunk=2)
    params = _params()
    batches = _batches(params)
    run_j = make_slab_round_runner(_loss_fn, ch, ad, fl, backend="jnp")
    run_s = make_slab_round_runner(_loss_fn, ch, ad, fl,
                                   backend="pallas_sharded",
                                   mesh=make_client_mesh((1,)))
    keys = jnp.stack([jax.random.fold_in(jax.random.key(7), t)
                      for t in range(3)])
    stacked = jax.tree.map(lambda b: jnp.stack([b] * 3), batches)
    st_j, ms_j = run_j(init_train_state(ad, params), keys, stacked)
    st_s, ms_s = run_s(init_train_state(ad, params, shards=1), keys, stacked)
    np.testing.assert_array_equal(np.asarray(ms_j.n_participants),
                                  np.asarray(ms_s.n_participants))
    np.testing.assert_allclose(np.asarray(st_j.w), np.asarray(st_s.w),
                               rtol=1e-5, atol=1e-5)


def test_zero_participation_skips_update():
    """A dead round must not divide by zero or move the server: state
    carries over bitwise, the round counter advances, and the metric
    records n_participants == 0. (``sample_rate=0.0`` is rejected at
    config time since PR 7, so the dead round is produced the way it
    happens in the field: a tiny rate and an unlucky round key.)"""
    ch, ad, fl = _configs(sample_rate=0.05)
    from repro.core import round_participation
    mask, _ = round_participation(jax.random.key(2), fl)
    assert float(jnp.sum(mask)) == 0.0     # pinned dead-round key
    params = _params()
    batches = _batches(params)
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend="pallas")
    st0 = init_train_state(ad, params)
    st1, m = step(st0, jax.random.key(2), batches)
    assert int(st1.step) == int(st0.step) + 1
    np.testing.assert_array_equal(np.asarray(st0.w), np.asarray(st1.w))
    for a, b in zip(st0.opt, st1.opt):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(st0.alpha_hat),
                                  np.asarray(st1.alpha_hat))
    assert float(m.n_participants) == 0.0
    assert np.isfinite(float(m.loss))


# ---------------------------------------------------------------------------
# Per-client aggregation weights
# ---------------------------------------------------------------------------

def test_uniform_weights_reduce_to_unweighted():
    """weights == (1, ..., 1) is the unweighted path, bitwise (the
    normaliser sum(m * 1) == sum(m) and the gain m * 1 == m)."""
    ch, ad, fl_none = _configs(sample_rate=0.5, client_chunk=2)
    _, _, fl_ones = _configs(sample_rate=0.5, client_chunk=2,
                             client_weights=(1.0,) * N)
    st_a, _ = _trajectory(ch, ad, fl_none, "pallas", jit=False)
    st_b, _ = _trajectory(ch, ad, fl_ones, "pallas", jit=False)
    for a, b in zip(_state_arrays(st_a), _state_arrays(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weighted_aggregate_matches_closed_form():
    """sum(m w h g) / sum(m w): verified against a hand-computed
    aggregate from the same draws, interference off."""
    w = tuple(float(i + 1) for i in range(N))
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.0)
    _, ad, fl = _configs(sample_rate=0.7, client_chunk=2,
                         client_weights=w)
    params = _params()
    spec = make_slab_spec(params)
    batches = _batches(params)

    def client_fn(p, b):
        g = jax.grad(_loss_fn)(p, b)
        return g, _loss_fn(p, b)

    key = jax.random.key(21)
    parts = streamed_round_parts(key, ch, fl, spec, client_fn, params,
                                 client_batches=batches, use_kernels=False)
    mask, gain = round_participation(key, fl)
    kh, _ = jax.random.split(key)
    h = sample_fading(kh, ch, (N,))
    from repro.core import stack_to_slab
    grads = jax.vmap(lambda b: jax.grad(_loss_fn)(params, b))(batches)
    g_stack = stack_to_slab(spec, grads)
    norm = float(jnp.sum(gain))
    expected = np.asarray(
        jnp.sum((h * gain)[:, None] * g_stack, axis=0) / norm)
    np.testing.assert_allclose(np.asarray(parts.g_slab), expected,
                               rtol=1e-5, atol=1e-6)
    assert float(parts.norm) == pytest.approx(norm)
    assert float(parts.n_participants) == float(jnp.sum(mask))


def test_datasize_weights_streamed_matches_jnp_oracle():
    """Regression for the ``--client-weights datasize`` launch path
    (PR 7): weights proportional to per-client dataset sizes, combined
    with partial participation AND a multi-chunk streamed round, must
    track the jnp oracle — the weight schedule is sliced per chunk from
    the SAME full (N,) gain vector on every backend."""
    sizes = (4.0, 2.0, 7.0, 1.0, 3.0, 5.0, 2.0, 8.0)   # len(parts_i)
    ch, ad, fl = _configs(sample_rate=0.5, client_chunk=3,
                          client_weights=sizes)
    st_j, m_j = _trajectory(ch, ad, fl, "jnp")
    st_p, m_p = _trajectory(ch, ad, fl, "pallas")
    assert float(m_j.n_participants) == float(m_p.n_participants)
    # RoundMetrics accounting agrees too: the loss is the mean over
    # PARTICIPATING clients and the norms are of the weighted aggregate.
    np.testing.assert_allclose(float(m_j.loss), float(m_p.loss), rtol=1e-5)
    np.testing.assert_allclose(float(m_j.grad_norm), float(m_p.grad_norm),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m_j.noisy_grad_norm),
                               float(m_p.noisy_grad_norm), rtol=1e-5)
    for a, b in zip(_state_arrays(st_j), _state_arrays(st_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    # And the weighting changed the aggregate: uniform weights over the
    # same draws land on a different trajectory.
    _, _, fl_u = _configs(sample_rate=0.5, client_chunk=3)
    st_u, _ = _trajectory(ch, ad, fl_u, "pallas")
    assert not np.allclose(np.asarray(st_p.w), np.asarray(st_u.w),
                           rtol=1e-6, atol=1e-7)


def test_flconfig_validates_streaming_fields():
    with pytest.raises(ValueError):
        FLConfig(n_clients=4, sample_rate=1.5)
    with pytest.raises(ValueError):
        FLConfig(n_clients=4, sample_rate=-0.1)
    with pytest.raises(ValueError, match="dead"):
        FLConfig(n_clients=4, sample_rate=0.0)   # every round dead (PR 7)
    with pytest.raises(ValueError):
        FLConfig(n_clients=4, client_chunk=0)
    with pytest.raises(ValueError):
        FLConfig(n_clients=4, client_weights=(1.0, 2.0))     # wrong len
    with pytest.raises(ValueError):
        FLConfig(n_clients=4, client_weights=(1.0, -1.0, 1.0, 1.0))
    with pytest.raises(ValueError):
        FLConfig(n_clients=4, client_weights=(0.0,) * 4)     # zero sum
    fl = FLConfig(n_clients=4, client_weights=[1, 2, 3, 4])
    assert fl.client_weights == (1.0, 2.0, 3.0, 4.0)
    assert fl.dynamic_norm and fl.dynamic_round
    assert not FLConfig(n_clients=4).dynamic_round
    assert FLConfig(n_clients=4, client_chunk=2).dynamic_round
    assert not FLConfig(n_clients=4, client_chunk=2).dynamic_norm


# ---------------------------------------------------------------------------
# Kernel level: accumulating / chunked transmit
# ---------------------------------------------------------------------------

def test_transmit_acc_chaining_matches_ref():
    from repro.kernels.ota_channel import ota_transmit_slab
    from repro.kernels.ref import ota_transmit_ref
    d, n = 300, 12
    g = jax.random.normal(jax.random.key(0), (n, d))
    h = jax.random.uniform(jax.random.key(1), (n,), minval=0.5, maxval=1.5)
    full = ota_transmit_ref(g, h, n_total=n)
    # Chained accumulation across two launches == one resident launch.
    acc = ota_transmit_slab(g[:4], h[:4], n_total=n,
                            acc=jnp.zeros((d,), jnp.float32), interpret=True)
    acc = ota_transmit_slab(g[4:], h[4:], n_total=n, acc=acc, interpret=True)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                               rtol=1e-6, atol=1e-6)
    # In-kernel row chunking (padded grid) == the same sum.
    out = ota_transmit_slab(g, h, n_total=n, row_chunk=5, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               rtol=1e-6, atol=1e-6)
    ref = ota_transmit_ref(g, h, n_total=n, row_chunk=5)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


def test_transmit_acc_refuses_quantize():
    from repro.kernels.ota_channel import ota_transmit_slab
    g = jnp.ones((4, 128))
    h = jnp.ones((4,))
    with pytest.raises(ValueError, match="quantize"):
        ota_transmit_slab(g, h, n_total=4, quantize=True,
                          acc=jnp.zeros((128,), jnp.float32), interpret=True)


def test_streamed_parts_single_vs_multi_chunk():
    """The chunked scan and the single-chunk path accumulate the same
    partial (f32 reassociation only)."""
    ch, ad, _ = _configs()
    _, _, fl1 = _configs(client_chunk=N)
    _, _, fl2 = _configs(client_chunk=2)
    params = _params()
    spec = make_slab_spec(params)
    batches = _batches(params)

    def client_fn(p, b):
        return jax.grad(_loss_fn)(p, b), _loss_fn(p, b)

    key = jax.random.key(3)
    p1 = streamed_round_parts(key, ch, fl1, spec, client_fn, params,
                              client_batches=batches, use_kernels=False)
    p2 = streamed_round_parts(key, ch, fl2, spec, client_fn, params,
                              client_batches=batches, use_kernels=False)
    np.testing.assert_allclose(np.asarray(p1.g_slab), np.asarray(p2.g_slab),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(p1.loss_sum), float(p2.loss_sum),
                               rtol=1e-6)


def test_batch_gen_round():
    """In-graph batch synthesis: no (N, ...) batch ever materialised —
    the runner scans over keys only."""
    ch, ad, fl = _configs(n_clients=16, client_chunk=4, sample_rate=0.75)
    params = {"w": jax.random.normal(jax.random.key(0), (64,))}

    def loss_fn(p, b):
        return jnp.mean((p["w"] - jnp.sin(b["phase"])) ** 2)

    def batch_gen(key, idx):
        return {"phase": idx.astype(jnp.float32) * 0.1}

    run = make_slab_round_runner(loss_fn, ch, ad, fl, backend="pallas",
                                 batch_gen=batch_gen)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(7), t)
                      for t in range(3)])
    st, ms = run(init_train_state(ad, params), keys)
    assert int(st.step) == 3
    assert np.all(np.isfinite(np.asarray(st.w)))
    n_parts = np.asarray(ms.n_participants)
    assert n_parts.shape == (3,)
    assert np.all(n_parts >= 0) and np.all(n_parts <= 16)


def test_batch_gen_requires_dynamic_round():
    ch, ad, fl = _configs()     # no chunk, no sampling: resident path
    with pytest.raises(ValueError, match="streamed"):
        make_slab_round_step(_loss_fn, ch, ad, fl, backend="pallas",
                             batch_gen=lambda k, i: {"x": i})


# ---------------------------------------------------------------------------
# Satellite: configurable forced host-device count
# ---------------------------------------------------------------------------

def test_host_device_override(monkeypatch):
    from repro.launch.hostdev import (DEFAULT_HOST_DEVICES,
                                      host_device_override,
                                      mesh_device_count)
    monkeypatch.delenv("REPRO_HOST_DEVICES", raising=False)
    assert host_device_override([]) == DEFAULT_HOST_DEVICES
    assert host_device_override(["--host-devices", "12"]) == 12
    assert host_device_override(["--host-devices=3"]) == 3
    assert host_device_override(["--host-devices", "bogus"]) == \
        DEFAULT_HOST_DEVICES
    monkeypatch.setenv("REPRO_HOST_DEVICES", "5")
    assert host_device_override([]) == 5
    assert host_device_override(["--host-devices", "12"]) == 12  # flag wins
    # mesh_device_count floors at the override but still tracks the
    # largest requested mesh.
    assert mesh_device_count(["--meshes", "2"], "--meshes") == 5
    assert mesh_device_count(["--meshes", "16"], "--meshes") == 16
    monkeypatch.delenv("REPRO_HOST_DEVICES")
    assert mesh_device_count(
        ["--meshes", "2", "--host-devices", "2"], "--meshes") == 2
