"""Pallas interpret-mode policy, shared by every kernel in this package.

The kernels target TPU; everywhere else (the CPU containers the tests
and CI run on, GPU hosts) they must run in Pallas interpret mode. The
old hardcoded ``interpret=True`` defaults made compiled TPU runs opt-in
at every call site; instead the default is now ``None`` = *auto*:
compiled (``interpret=False``) when jax's default backend is a TPU,
interpreted otherwise.

Resolution order for ``resolve_interpret(flag)``:

1. an explicit ``True``/``False`` (kernel kwarg or config field) wins;
2. the ``REPRO_PALLAS_INTERPRET`` environment variable (``1/true/on``
   or ``0/false/off``) overrides the platform default — the escape
   hatch for forcing interpret mode on a TPU (kernel debugging) or
   asserting compiled mode in a launch script;
3. otherwise ``jax.default_backend() != "tpu"``.

The jax backend query initialises jax's platform on first use, which is
safe here: resolution happens at kernel-call (trace) time, long after
any ``--xla_force_host_platform_device_count`` override was installed.
"""

from __future__ import annotations

import os
from typing import Optional

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Platform/env default: interpret everywhere except on real TPU."""
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        if env.lower() in _TRUE:
            return True
        if env.lower() in _FALSE:
            return False
        raise ValueError(
            f"{INTERPRET_ENV}={env!r} is not a boolean; use one of "
            f"{_TRUE + _FALSE}")
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """An explicit flag wins; ``None`` means auto (env, then platform)."""
    return default_interpret() if interpret is None else bool(interpret)
