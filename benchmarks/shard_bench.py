"""Sharded-slab round benchmark (separate process on purpose).

The main bench process must keep jax's real single-device view (see
tests/conftest.py), and jax locks the device count at first backend
init — so the ``pallas_sharded`` column of BENCH_round_step.json is
produced here, in a subprocess spawned by ``benchmarks/run.py`` with the
host-device override above, and shipped back as JSON on stdout.

Like the other pallas numbers on this CPU container, the wall time
measures interpret mode; the hardware-relevant column is the per-device
bytes model: each of P devices streams its N/P client rows once for the
MAC, does the 7-transfer fused update on its d/P slab slice, and pays
ring-collective traffic for the model broadcast (all_gather), the MAC
reduce-scatter, and — this being the pytree-per-round API — the
boundary materialisation of params + state each call (the resident
loop in BENCH_train_loop.json drops that last term; see
benchmarks/train_loop_bench.py for the side-by-side).

    PYTHONPATH=src python -m benchmarks.shard_bench --sizes 16384 65536
"""

import os
import sys

from repro.launch.hostdev import (force_host_devices, mesh_device_count,
                                  positive_int)

force_host_devices(mesh_device_count(sys.argv, "--mesh"))

import argparse
import json
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def bench_sharded_round_step(n_params: int, n_clients: int = 8,
                             mesh_shape=(2,), iters: int = 5) -> dict:
    import jax
    from benchmarks.kernel_bench import _round_step_case
    from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                            init_server, make_round_step)
    from repro.launch.mesh import make_client_mesh

    params, loss_fn, batches = _round_step_case(n_params, n_clients)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.02, alpha=1.5)
    mesh = make_client_mesh(mesh_shape)
    rs = make_round_step(loss_fn, ch, ad, FLConfig(n_clients=n_clients),
                         backend="pallas_sharded", mesh=mesh)
    state = init_server(params, ad)
    key = jax.random.key(2)
    run = lambda: rs(params, state, key, batches)
    jax.block_until_ready(run())          # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = run()
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    n_dev = 1
    for s in mesh_shape:
        n_dev *= s
    # Per-device f32 words: MAC reads (N/P + 2)d, update moves 7 d/P;
    # collectives: d (all_gather model broadcast) + 2d (reduce-scatter
    # of [g, clean]) + (k+1)d boundary materialisation of the k state
    # slabs + params this pytree-per-round API pays each call (k = 2
    # for adam_ota: delta, nu).
    k_rows = 2
    bytes_dev = 4 * (n_params * (n_clients // n_dev + 2)
                     + 7 * n_params // n_dev
                     + (1 + 2 + (k_rows + 1)) * n_params)
    shape_tag = "x".join(str(s) for s in mesh_shape)
    from repro.kernels.interpret import INTERPRET_ENV, resolve_interpret
    return dict(
        name=f"round_step_pallas_sharded_{n_params}",
        backend="pallas_sharded", n_params=n_params, n_clients=n_clients,
        interpret={"resolved": resolve_interpret(None),
                   "env": os.environ.get(INTERPRET_ENV)},
        mesh=shape_tag, us_per_round=us, us_per_call=us,
        hbm_bytes_est=bytes_dev,
        derived=f"hbm_bytes_per_device={bytes_dev};mesh={shape_tag}",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[1 << 14])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--mesh", default="2")
    ap.add_argument("--iters", type=positive_int, default=5)
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    records = [bench_sharded_round_step(n, args.clients, mesh_shape,
                                        args.iters) for n in args.sizes]
    json.dump(records, sys.stdout)


if __name__ == "__main__":
    main()
