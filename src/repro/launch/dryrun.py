import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape) program
on the production mesh with 512 placeholder host devices, and record the
roofline inputs (FLOPs, bytes, per-collective bytes, memory analysis).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single --out results/dryrun/x.json
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full matrix

The XLA flag above MUST precede any jax import (jax locks the device
count at first backend init) — which is why it is the first statement of
this module and why nothing else in the package sets it.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCHS, get_config
from repro.core.adaptive import AdaptiveConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import INPUT_SHAPES, shape_config
from repro.launch.steps import RunConfig, build_step

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
          "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
          "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-op output bytes summed over the compiled module.

    Lines look like:  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), ...
    The RESULT type (before the '=') is the data moved; '-start' variants
    are counted, '-done' skipped (same tensor).
    """
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for c in _COLLECTIVES:
            if (f" {c}(" in line or f" {c}-start(" in line):
                parts = line.split(" = ", 1)
                if len(parts) == 2:
                    rhs = parts[1]
                    # result TYPE is everything before the op token (handles
                    # tuple-typed results like "(f32[8], f32[8]) all-to-all(")
                    idx = rhs.find(f" {c}")
                    type_str = rhs[:idx] if idx > 0 else rhs.split("(", 1)[0]
                    out[c] += _shape_bytes(type_str)
                break
    return out


def _depth_variant(cfg, units: int):
    """Same arch at reduced depth with unrolled scans (for calibration)."""
    if cfg.family == "vlm":
        return dataclasses.replace(cfg, n_layers=cfg.cross_attn_period * units,
                                   scan_unroll=True)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=units, n_enc_layers=units,
                                   scan_unroll=True)
    return dataclasses.replace(cfg, n_layers=units, scan_unroll=True)


def _full_units(cfg) -> int:
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_period
    return cfg.n_layers


def _measure(cfg, mesh, run: RunConfig, shape_name: str) -> Dict:
    """Lower+compile one program; return cost/collective metrics."""
    pieces = build_step(cfg, mesh, run, shape_name)
    with mesh:   # ambient mesh: enables with_sharding_constraint in-model
        jitted = jax.jit(pieces.step_fn, in_shardings=pieces.in_shardings,
                         out_shardings=pieces.out_shardings,
                         donate_argnums=pieces.donate_argnums)
        lowered = jitted.lower(*pieces.args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    colls = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()
    out = dict(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collectives=colls,
    )
    if mem is not None:
        out["memory"] = dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", -1)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", -1)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", -1)),
            generated_code_bytes=int(
                getattr(mem, "generated_code_size_in_bytes", -1)),
        )
    return out


def calibrate(cfg, mesh, run: RunConfig, shape_name: str) -> Dict:
    """Per-layer cost calibration: XLA cost analysis counts while (scan)
    bodies ONCE, so the full-depth scanned program under-reports. We
    compile depth-2 and depth-4 *unrolled* variants at full width and
    extrapolate each metric linearly in depth:

        metric(L) = fixed + per_layer * L

    (exact for layer-homogeneous stacks; embed/unembed/xent/optimizer
    tails land in `fixed`).
    """
    u2, u4 = 1, 2
    if cfg.family not in ("vlm",):
        u2, u4 = 2, 4
    m2 = _measure(_depth_variant(cfg, u2), mesh, run, shape_name)
    m4 = _measure(_depth_variant(cfg, u4), mesh, run, shape_name)
    units = _full_units(cfg)

    def extrap(a, b):
        per = (b - a) / (u4 - u2)
        fixed = a - per * u2
        return max(fixed + per * units, 0.0)

    out = dict(
        flops=extrap(m2["flops"], m4["flops"]),
        bytes_accessed=extrap(m2["bytes_accessed"], m4["bytes_accessed"]),
        collectives={c: extrap(m2["collectives"][c], m4["collectives"][c])
                     for c in m2["collectives"]},
        calib_points={"u2": u2, "u4": u4, "m2": m2["flops"], "m4": m4["flops"]},
    )
    out["collective_bytes"] = float(sum(out["collectives"].values()))
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            run: RunConfig, do_calibrate: bool = True,
            overrides: Optional[Dict] = None) -> Dict:
    rec: Dict = dict(arch=arch, shape=shape_name,
                     mesh="multi" if multi_pod else "single",
                     optimizer=run.adaptive.optimizer, fsdp=run.fsdp,
                     shard_cache_seq=run.shard_cache_seq,
                     state_dtype=run.state_dtype, ok=False,
                     overrides=overrides or {})
    t0 = time.time()
    try:
        base_cfg = get_config(arch)
        if overrides:
            base_cfg = dataclasses.replace(base_cfg, **overrides)
        cfg = shape_config(base_cfg, shape_name)
        mesh = make_production_mesh(multi_pod=multi_pod)
        pieces = build_step(base_cfg, mesh, run, shape_name)
        with mesh:   # ambient mesh for in-model sharding constraints
            jitted = jax.jit(pieces.step_fn, in_shardings=pieces.in_shardings,
                             out_shardings=pieces.out_shardings,
                             donate_argnums=pieces.donate_argnums)
            lowered = jitted.lower(*pieces.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        cost = compiled.cost_analysis() or {}
        mem = compiled.memory_analysis()
        text = compiled.as_text()
        colls = collective_bytes(text)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            # NOTE: scanned-body costs counted once by XLA — see `calibrated`.
            flops_per_device_scanned=float(cost.get("flops", -1.0)),
            bytes_per_device_scanned=float(cost.get("bytes accessed", -1.0)),
            collectives_scanned=colls,
            n_params=cfg.n_params(),
            n_active_params=cfg.n_active_params(),
            window=cfg.window,
        )
        if mem is not None:
            rec["memory"] = dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes", -1)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", -1)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", -1)),
                generated_code_bytes=int(
                    getattr(mem, "generated_code_size_in_bytes", -1)),
            )
        del compiled, lowered, text
        if do_calibrate:
            rec["calibrated"] = calibrate(cfg, mesh, run, shape_name)
    except Exception as e:  # noqa: BLE001 — record and continue the matrix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=10)
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true",
                    help="run the full 10x4 matrix on --mesh")
    ap.add_argument("--optimizer", default="adam_ota")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--shard-cache-seq", action="store_true")
    ap.add_argument("--state-dtype", default="float32")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (perf experiments)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    run = RunConfig(
        adaptive=AdaptiveConfig(optimizer=args.optimizer),
        fsdp=args.fsdp, shard_cache_seq=args.shard_cache_seq,
        state_dtype=args.state_dtype)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = eval(v, {}, {})   # ints/floats/None/True
        except Exception:
            pass
        overrides[k] = v

    combos = ([(a, s) for a in ARCHS for s in INPUT_SHAPES] if args.all
              else [(args.arch, args.shape)])
    results = []
    for arch, shape_name in combos:
        rec = run_one(arch, shape_name, args.mesh == "multi", run,
                      do_calibrate=not args.no_calibrate,
                      overrides=overrides or None)
        status = "OK " if rec["ok"] else "FAIL"
        cal = rec.get("calibrated", {})
        print(f"[{status}] {arch:24s} {shape_name:12s} {args.mesh:6s} "
              f"{rec.get('total_s', 0):7.1f}s "
              f"flops/dev={cal.get('flops', rec.get('flops_per_device_scanned', 0)):.3e} "
              f"coll={cal.get('collective_bytes', 0):.3e}B"
              + ("" if rec["ok"] else f"  {rec.get('error', '')[:120]}"),
              flush=True)
        results.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results if args.all or len(results) > 1 else results[0],
                      f, indent=2)


if __name__ == "__main__":
    main()
