"""Pallas interpret-mode policy, shared by every kernel in this package.

The kernels target TPU; everywhere else (the CPU containers the tests
and CI run on, GPU hosts) they must run in Pallas interpret mode. The
old hardcoded ``interpret=True`` defaults made compiled TPU runs opt-in
at every call site; instead the default is now ``None`` = *auto*:
compiled (``interpret=False``) when jax's default backend is a TPU,
interpreted otherwise.

Resolution order for ``resolve_interpret(flag)``:

1. an explicit ``True``/``False`` (kernel kwarg or config field) wins;
2. the ``REPRO_PALLAS_INTERPRET`` environment variable (``1/true/on``
   or ``0/false/off``) overrides the platform default — the escape
   hatch for forcing interpret mode on a TPU (kernel debugging) or
   asserting compiled mode in a launch script;
3. otherwise ``jax.default_backend() != "tpu"``.

The jax backend query initialises jax's platform on first use, which is
safe here: resolution happens at kernel-call (trace) time, long after
any ``--xla_force_host_platform_device_count`` override was installed.
"""

from __future__ import annotations

import os
from typing import Optional

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

# Interpret-mode grid coarsening cap: axis units per interpreted grid
# step (columns for the channel kernels, slab rows for the update
# kernel). See ``coarse_block``.
INTERPRET_BLOCK_CAP = 1 << 18

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def default_interpret() -> bool:
    """Platform/env default: interpret everywhere except on real TPU."""
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        if env.lower() in _TRUE:
            return True
        if env.lower() in _FALSE:
            return False
        raise ValueError(
            f"{INTERPRET_ENV}={env!r} is not a boolean; use one of "
            f"{_TRUE + _FALSE}")
    # repro-lint: lazy-import (jax.default_backend() initializes the
    # platform; importing this module must stay side-effect-free so
    # XLA_FLAGS set after import still take effect)
    import jax
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """An explicit flag wins; ``None`` means auto (env, then platform)."""
    return default_interpret() if interpret is None else bool(interpret)


def coarse_block(n: int, block: int, interpret: bool,
                 cap: int = INTERPRET_BLOCK_CAP) -> int:
    """Interpret-mode grid coarsening: the block size to launch with.

    Compiled launches keep the hardware tile ``block`` untouched. In
    interpret mode the grid loop is evaluated step by step on the host
    (each step paying block-index resolution + operand slicing on the
    full buffers), so a d = 256k slab at the TPU tile size means 512
    interpreted steps per launch — the host overhead that made the
    interpret-mode slab engine slower than the jnp path it replaces.
    Here the block grows to cover the whole padded axis (capped at
    ``cap`` axis units, in multiples of ``block``), collapsing the grid
    to ~1 step.

    Value-safe by construction for this package's kernels: every
    per-coordinate output and every per-LANE-block scale is computed
    from within-column / within-128-block data only — invariant to how
    the d axis is tiled (asserted bitwise against the fixed-tile launch
    in the test suite). The one exception is the pilot-stats scalar
    reductions, whose cross-tile accumulation order follows the grid —
    those re-associate at the ULP (asserted to ~1 ULP in the same
    test), within the estimator's existing cross-backend tolerance.
    """
    if not interpret or n <= block:
        return block
    padded = -(-n // block) * block
    return min(padded, max(block, (cap // block) * block))
