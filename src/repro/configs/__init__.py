"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the full-size ModelConfig (dry-run only);
``smoke_config(arch)`` returns the reduced same-family variant (2 layers,
d_model <= 512, <= 4 experts) that actually executes on CPU in tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.model import ModelConfig

ARCHS: List[str] = [
    "starcoder2-15b",
    "minicpm3-4b",
    "rwkv6-7b",
    "qwen2.5-14b",
    "kimi-k2-1t-a32b",
    "qwen3-14b",
    "whisper-medium",
    "llama-3.2-vision-11b",
    "hymba-1.5b",
    "qwen3-moe-235b-a22b",
]

_MODULES: Dict[str, str] = {
    "starcoder2-15b": "starcoder2_15b",
    "minicpm3-4b": "minicpm3_4b",
    "rwkv6-7b": "rwkv6_7b",
    "qwen2.5-14b": "qwen2_5_14b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-14b": "qwen3_14b",
    "whisper-medium": "whisper_medium",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "hymba-1.5b": "hymba_1_5b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; options: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model <= 512, <= 4 experts."""
    cfg = get_config(arch)
    updates = dict(
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab=512,
        remat=False,
        enc_seq=24,
        n_enc_layers=2 if cfg.family == "encdec" else 0,
        n_img_tokens=16,
        window=min(cfg.window, 64) if cfg.window else None,
        kv_chunk=None,
    )
    if cfg.family == "rwkv":
        updates["n_heads"] = 4          # head_dim = 32
        updates["rwkv_lora_rank"] = 16
        updates["rwkv_chunk"] = 16
    if cfg.family == "mla":
        updates.update(q_lora_rank=48, kv_lora_rank=32, qk_nope_head_dim=16,
                       qk_rope_head_dim=8, v_head_dim=16)
    if cfg.n_experts:
        updates.update(n_experts=4, top_k=2)
    if cfg.family == "vlm":
        updates["n_layers"] = cfg.cross_attn_period * 2   # 2 groups
    if cfg.n_meta_tokens:
        updates["n_meta_tokens"] = 8
    return dataclasses.replace(cfg, **updates)
