"""Sharded slab engine: backend="pallas_sharded" parity and contracts.

In-process tests run on a (1,)-mesh (the pytest process keeps jax's real
single-device view — see conftest.py), covering both the per-round
pytree API and the slab-RESIDENT multi-round loop (scan inside
shard_map, all six optimizers). The multi-device acceptance — resident
trajectory parity with the per-round jnp reference at 1e-5 over 5 full
rounds for ALL six optimizers on mesh shapes (1,), (2,) and (4, 2),
plus bitwise rerun determinism — runs ``repro.launch.shard_check`` in a
subprocess that forces 8 host devices before jax initialises.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_auto_mesh
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, init_train_state, make_round_step,
                        make_slab_round_runner, unpack_train_state)
from repro.core.shard import client_axes_of, n_client_shards

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SHAPES = [(3, 45), (130,), (1,), (257,)]


def _params(key):
    ks = jax.random.split(key, len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _assert_trees_close(a, b, tol):
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("optimizer", ["adam_ota", "amsgrad_ota", "fedavg"])
def test_single_shard_mesh_matches_jnp(optimizer):
    """The (1,)-mesh exercises the whole sharded code path (shard_map,
    partial-MAC kernel, psum, slice update, regather) in-process."""
    params = _params(jax.random.key(0))
    n = 4
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(1), (n,) + p.shape),
        params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer=optimizer, lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)
    mesh = make_auto_mesh((1,), ("data",))

    outs = {}
    for backend, mesh_arg in (("jnp", None), ("pallas_sharded", mesh)):
        rs = make_round_step(_loss_fn, ch, ad, fl, backend=backend,
                             mesh=mesh_arg)
        p, s = params, init_server(params, ad)
        for t in range(2):
            p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(9), t),
                         batches)
        outs[backend] = (p, s, m)
    p_r, s_r, m_r = outs["jnp"]
    p_s, s_s, m_s = outs["pallas_sharded"]
    _assert_trees_close(p_r, p_s, 1e-5)
    _assert_trees_close(s_r.delta, s_s.delta, 1e-5)
    _assert_trees_close(s_r.nu, s_s.nu, 1e-5)
    assert int(s_s.step) == 2
    np.testing.assert_allclose(float(m_r.loss), float(m_s.loss), rtol=1e-6)
    np.testing.assert_allclose(float(m_r.noisy_grad_norm),
                               float(m_s.noisy_grad_norm), rtol=1e-4)
    np.testing.assert_allclose(float(m_r.grad_norm), float(m_s.grad_norm),
                               rtol=1e-4)


@pytest.mark.parametrize("optimizer", ["adagrad_ota", "adam_ota",
                                       "amsgrad_ota", "yogi_ota",
                                       "fedavgm", "fedavg"])
def test_resident_trajectory_matches_jnp_single_shard_mesh(optimizer):
    """Multi-round trajectory parity of the slab-RESIDENT loop (scan
    inside shard_map, state carried as slab slices — no regather in the
    scanned body) vs the per-round jnp pytree reference, 5 rounds, all
    six optimizers, on the in-process (1,)-mesh. Multi-device meshes are
    covered by the shard_check acceptance below."""
    params = _params(jax.random.key(4))
    n, rounds = 4, 5
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(5), (n,) + p.shape),
        params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer=optimizer, lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)

    rs = make_round_step(_loss_fn, ch, ad, fl, backend="jnp")
    p_ref, s_ref = params, init_server(params, ad)
    for t in range(rounds):
        p_ref, s_ref, m_ref = rs(p_ref, s_ref,
                                 jax.random.fold_in(jax.random.key(6), t),
                                 batches)

    mesh = make_auto_mesh((1,), ("data",))
    run = make_slab_round_runner(_loss_fn, ch, ad, fl,
                                 backend="pallas_sharded", mesh=mesh)
    st = init_train_state(ad, params, shards=1)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(6), t)
                      for t in range(rounds)])
    st, ms = run(st, keys, jax.tree.map(
        lambda b: jnp.stack([b] * rounds), batches))
    p_res, s_res = unpack_train_state(ad, st)

    _assert_trees_close(p_ref, p_res, 1e-5)
    _assert_trees_close(s_ref.delta, s_res.delta, 1e-5)
    _assert_trees_close(s_ref.nu, s_res.nu, 1e-5)
    assert int(st.step) == rounds
    assert ms.loss.shape == (rounds,)
    np.testing.assert_allclose(float(m_ref.loss), float(ms.loss[-1]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m_ref.grad_norm),
                               float(ms.grad_norm[-1]), rtol=1e-4)
    np.testing.assert_allclose(float(m_ref.noisy_grad_norm),
                               float(ms.noisy_grad_norm[-1]), rtol=1e-4)


def test_two_launches_per_device_per_round(monkeypatch):
    """On a (1,)-mesh each round is exactly one partial-MAC launch and
    one slab-slice update launch per device."""
    from repro.core import shard as core_shard
    from repro.kernels import adaptive_update as au_mod
    from repro.kernels import ota_channel as oc_mod

    calls = {"ota": 0, "update": 0}
    real_ota, real_upd = oc_mod.ota_transmit_slab, au_mod.adaptive_update_slab
    # core.shard binds ota_transmit_slab at import time; adaptive still
    # imports adaptive_update_slab lazily, so patch its defining module.
    monkeypatch.setattr(
        core_shard, "ota_transmit_slab",
        lambda *a, **k: (calls.__setitem__("ota", calls["ota"] + 1),
                         real_ota(*a, **k))[1])
    monkeypatch.setattr(
        au_mod, "adaptive_update_slab",
        lambda *a, **k: (calls.__setitem__("update", calls["update"] + 1),
                         real_upd(*a, **k))[1])

    params = _params(jax.random.key(2))
    n = 2
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (n,) + p.shape),
        params)
    ad = AdaptiveConfig(optimizer="adam_ota")
    rs = make_round_step(_loss_fn, OTAChannelConfig(), ad, FLConfig(n_clients=n),
                         jit=False, backend="pallas_sharded",
                         mesh=make_auto_mesh((1,), ("data",)))
    rs(params, init_server(params, ad), jax.random.key(0), batches)
    assert calls == {"ota": 1, "update": 1}, calls


@pytest.mark.parametrize("uplink", ["f32", "int8"])
def test_power_control_on_sharded_backend(uplink):
    """Satellite: truncated channel inversion (power_control +
    pc_threshold) on the pallas_sharded backend — the effective 0/1
    fading must flow through the sharded transmit/MAC exactly like the
    jnp reference (1e-5 at f32; one quantization step at int8)."""
    from repro.core import UplinkConfig
    params = _params(jax.random.key(7))
    n = 8   # enough clients that a truncated (h == 0) draw occurs
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(8), (n,) + p.shape),
        params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1, fading="rayleigh",
                          power_control=True, pc_threshold=0.6,
                          uplink=UplinkConfig(mode=uplink))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)

    outs = {}
    for backend, mesh_arg in (("jnp", None),
                              ("pallas_sharded",
                               make_auto_mesh((1,), ("data",)))):
        rs = make_round_step(_loss_fn, ch, ad, fl, backend=backend,
                             mesh=mesh_arg)
        p, s = params, init_server(params, ad)
        for t in range(2):
            p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(12), t),
                         batches)
        outs[backend] = (p, s, m)
    p_r, s_r, m_r = outs["jnp"]
    p_s, s_s, m_s = outs["pallas_sharded"]
    tol = 1e-5 if uplink == "f32" else 5e-3
    _assert_trees_close(p_r, p_s, tol)
    _assert_trees_close(s_r.delta, s_s.delta, tol)
    _assert_trees_close(s_r.nu, s_s.nu, tol)
    # the truncated-inversion fading is 0/1 and identical on both paths
    np.testing.assert_allclose(float(m_r.fading_mean),
                               float(m_s.fading_mean), rtol=1e-6)
    assert 0.0 < float(m_r.fading_mean) < 1.0   # some client WAS silenced


def test_sharded_backend_validation():
    ch, fl = OTAChannelConfig(), FLConfig(n_clients=4)
    ad = AdaptiveConfig()
    # mesh is mandatory
    with pytest.raises(ValueError, match="mesh"):
        make_round_step(_loss_fn, ch, ad, fl, backend="pallas_sharded")
    from repro.core.shard import shard_round_step

    # clients must divide into the client-shard count (validated before
    # any device work, so a 2-shard stand-in mesh suffices on 1 device)
    class _TwoShardMesh:
        axis_names = ("data",)
        shape = {"data": 2}

    with pytest.raises(ValueError, match="divisible"):
        shard_round_step(_loss_fn, ch, ad,
                         dataclasses.replace(fl, n_clients=3),
                         _TwoShardMesh())
    # a model-only mesh has no client axes
    with pytest.raises(ValueError, match="client"):
        shard_round_step(_loss_fn, ch, ad, fl,
                         make_auto_mesh((1,), ("model",)))


def test_client_axes_helpers():
    mesh = make_auto_mesh((1,), ("data",))
    assert client_axes_of(mesh) == ("data",)
    assert n_client_shards(mesh) == 1


def test_configs_accept_sharded_backend():
    from repro.core.fl import _resolve_backend
    backend, ch, ad = _resolve_backend(
        None, OTAChannelConfig(backend="pallas_sharded"), AdaptiveConfig())
    assert backend == "pallas_sharded"
    assert ch.backend == ad.backend == "pallas_sharded"
    # explicit argument still wins
    backend, _, _ = _resolve_backend("jnp",
                                     OTAChannelConfig(backend="pallas_sharded"),
                                     AdaptiveConfig())
    assert backend == "jnp"


def test_multi_device_parity_acceptance():
    """ACCEPTANCE: the slab-resident trajectories (single-device pallas
    and pallas_sharded on mesh shapes (1,), (2,) and (4, 2)) match the
    per-round jnp reference at 1e-5 over 5 full rounds for ALL six
    optimizers, and sharded reruns are bitwise deterministic — checked
    on 8 forced host devices in a subprocess
    (repro.launch.shard_check)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check",
         "--meshes", "1", "2", "4,2", "--rounds", "5", "--tol", "1e-5"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PARITY OK" in out.stdout, out.stdout
