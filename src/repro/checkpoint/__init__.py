"""Pytree + slab-state checkpointing (npz-based, dependency-free).

Two formats share one atomic-write core:

* ``save``/``load`` — generic pytrees ({params, server optimizer state,
  round counter, rng key}), leaves flattened to path-keyed arrays in one
  compressed .npz, structure rebuilt from the stored key paths on load
  (against a template tree).
* ``save_slab_state``/``load_slab_state`` — the slab-resident
  ``SlabTrainState`` (PR 3): the raw slabs are stored as-is (no
  pytree unpack — checkpointing is a boundary, but it is a *slab*
  boundary) together with a JSON fingerprint of the ``SlabSpec``
  layout, which ``load_slab_state`` verifies against the caller's spec
  so a resume can never silently re-pack into a drifted layout.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.slab_state import (SlabTrainState, check_spec_meta,
                                   spec_meta)

PyTree = Any
_SEP = "|"
_BF16 = "~bf16"   # npz cannot store ml_dtypes.bfloat16; stored as uint16 view

# In-flight async checkpoint writers (save_slab_state(blocking=False)).
# Joined at the next checkpoint boundary and by wait_for_async_saves();
# the list only ever holds host-side snapshots, so a pending entry
# never pins (or races) device buffers.
_PENDING_SAVES: List[threading.Thread] = []
_PENDING_LOCK = threading.Lock()
_PENDING_ERRORS: List[BaseException] = []


def wait_for_async_saves() -> None:
    """Join every in-flight ``save_slab_state(blocking=False)`` write.

    Call at loop exit (and before reading a file that may still be in
    flight). Re-raises the first background write failure, so a crashed
    async save cannot pass silently.
    """
    while True:
        with _PENDING_LOCK:
            if not _PENDING_SAVES:
                break
            t = _PENDING_SAVES.pop(0)
        t.join()
    with _PENDING_LOCK:
        if _PENDING_ERRORS:
            err = _PENDING_ERRORS[:]
            _PENDING_ERRORS.clear()
            raise RuntimeError(
                f"{len(err)} async checkpoint write(s) failed; first "
                f"failure: {err[0]!r}") from err[0]


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        ks = []
        for p in path:
            ks.append(str(getattr(p, "key", getattr(p, "idx", p))))
        arr = np.asarray(leaf)
        key = _SEP.join(ks)
        if arr.dtype == jnp.bfloat16:
            out[key + _BF16] = arr.view(np.uint16)
        else:
            out[key] = arr
    return out


def _atomic_savez(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """Write to a temp file in the same dir, then rename (atomic)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez_compressed(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def save(path: str, tree: PyTree) -> None:
    """Atomic save of a generic pytree."""
    _atomic_savez(path, _flatten(tree))


def load(path: str, template: PyTree) -> PyTree:
    """Restore into the structure (and dtypes) of ``template``."""
    with np.load(path) as data:
        stored = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in paths:
        ks = []
        for p in path_keys:
            ks.append(str(getattr(p, "key", getattr(p, "idx", p))))
        key = _SEP.join(ks)
        if key + _BF16 in stored:
            arr = jnp.asarray(stored[key + _BF16].view(jnp.bfloat16))
        elif key in stored:
            arr = stored[key]
        else:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if hasattr(leaf, "dtype"):
            arr = jnp.asarray(arr, dtype=leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def save_slab_state(path: str, state, extra: Optional[Dict[str, Any]] = None,
                    blocking: bool = True) -> None:
    """Atomic save of a ``SlabTrainState`` (slabs stored raw, no unpack).

    The layout fingerprint (``slab_state.spec_meta``) rides along so
    ``load_slab_state`` can verify the resuming process rebuilds the
    SAME layout. ``extra`` adds named arrays (e.g. an rng key) under an
    ``x_`` prefix.

    ``blocking=False`` overlaps the serialize+compress+write with the
    training loop: the device->host snapshot happens HERE, synchronously
    (``np.asarray`` materialises every slab before the call returns, so
    a donating runner is free to consume the buffers immediately after),
    and only the npz encode + atomic rename run on a background thread.
    Any previous in-flight write is joined first — checkpoints hit disk
    in order, at most one writer runs behind the loop, and the file
    bytes are IDENTICAL to the blocking path (same arrays, same
    deterministic zip). Join stragglers with
    :func:`wait_for_async_saves` at loop exit.
    """
    arrays = {"step": np.asarray(state.step), "w": np.asarray(state.w),
              "alpha_hat": np.asarray(state.alpha_hat),
              "spec_meta": np.asarray(json.dumps(spec_meta(state.spec)))}
    for i, slab in enumerate(state.opt):
        arrays[f"opt_{i}"] = np.asarray(slab)
    arrays["n_opt"] = np.asarray(len(state.opt))
    if getattr(state, "ef", None) is not None:
        arrays["ef"] = np.asarray(state.ef)
    for k, v in (extra or {}).items():
        arrays[f"x_{k}"] = np.asarray(v)
    if blocking:
        _atomic_savez(path, arrays)
        return
    wait_for_async_saves()

    def write():
        try:
            _atomic_savez(path, arrays)
        except BaseException as ex:          # surfaced by the next join
            with _PENDING_LOCK:
                _PENDING_ERRORS.append(ex)

    t = threading.Thread(target=write, name="ckpt-async-save", daemon=True)
    with _PENDING_LOCK:
        _PENDING_SAVES.append(t)
    t.start()


def load_slab_state(path: str, spec) -> Tuple[Any, Dict[str, np.ndarray]]:
    """Restore a ``SlabTrainState`` laid out by ``spec``.

    Raises if ``spec`` does not reproduce the checkpointed layout
    (shapes/dtypes/offsets/padding/shards) — resuming into a drifted
    layout would silently scramble the slabs. Returns
    ``(state, extra)`` with ``extra`` the ``x_``-prefixed arrays given
    at save time.
    """
    wait_for_async_saves()       # never read a file still in flight
    with np.load(path) as data:
        stored = {k: data[k] for k in data.files}
    check_spec_meta(spec, json.loads(str(stored["spec_meta"])), where=path)
    n_opt = int(stored["n_opt"])
    state = SlabTrainState(
        step=jnp.asarray(stored["step"], jnp.int32),
        w=jnp.asarray(stored["w"], jnp.float32),
        opt=tuple(jnp.asarray(stored[f"opt_{i}"], jnp.float32)
                  for i in range(n_opt)),
        # pre-alpha-loop checkpoints carry no tracker state: resume with
        # the unseeded sentinel (the next tracked round re-seeds the EMA)
        alpha_hat=jnp.asarray(stored.get("alpha_hat", np.zeros(())),
                              jnp.float32),
        spec=spec,
        # pre-EF checkpoints carry no residual rows: resume with None
        # (the caller re-allocates zeros if it wants to turn EF on).
        ef=(jnp.asarray(stored["ef"], jnp.float32)
            if "ef" in stored else None))
    extra = {k[2:]: v for k, v in stored.items() if k.startswith("x_")}
    return state, extra


def latest_round(ckpt_dir: str, prefix: str = "round_") -> Optional[str]:
    wait_for_async_saves()       # an in-flight file must be listable
    if not os.path.isdir(ckpt_dir):
        return None
    rounds = []
    for f in os.listdir(ckpt_dir):
        if f.startswith(prefix) and f.endswith(".npz"):
            try:
                rounds.append((int(f[len(prefix):-4]), f))
            except ValueError:
                continue
    if not rounds:
        return None
    return os.path.join(ckpt_dir, max(rounds)[1])
