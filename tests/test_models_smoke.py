"""Per-architecture smoke tests (REDUCED same-family variants): one
forward + one train-grad step + one decode step on CPU; shapes + no NaNs.
"""


import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models.model import build_model

B, S = 2, 32


def _batch(cfg, key=2):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (B, S), 0,
                                          cfg.vocab)}
    if cfg.family == "encdec":
        batch["audio_embed"] = jax.random.normal(
            jax.random.key(3), (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(
            jax.random.key(3), (B, cfg.n_img_tokens, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    assert cfg.n_layers <= 10 and cfg.d_model <= 512
    assert (cfg.n_experts or 0) <= 4
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0.0
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    _, cache = model.prefill(params, batch, length=S + cfg.n_meta_tokens + 8)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.asarray(S))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_dimensions(arch):
    """The FULL configs carry the exact assigned dimensions (checked
    without allocation via eval_shape)."""
    cfg = get_config(arch)
    expected = {
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expected
    if arch == "kimi-k2-1t-a32b":
        assert (cfg.n_experts, cfg.top_k) == (384, 8)
        assert cfg.n_params() > 1.0e12          # trillion-param MoE
        assert cfg.n_active_params() < 4.0e10   # ~32B active
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.n_experts, cfg.top_k) == (128, 8)
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16 and cfg.n_meta_tokens == 128


def test_param_counts_roughly_match_names():
    """Sanity: named sizes are in the right ballpark (same count basis as
    the model cards, embeddings included)."""
    approx = {
        "starcoder2-15b": (15e9, 0.25),
        "qwen2.5-14b": (14e9, 0.25),
        "qwen3-14b": (14e9, 0.25),
        "rwkv6-7b": (7e9, 0.35),
        "minicpm3-4b": (4e9, 0.35),
        "hymba-1.5b": (1.5e9, 0.4),
        "llama-3.2-vision-11b": (10e9, 0.35),  # decoder side of the 11B
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).n_params()
        assert abs(n - target) / target < tol, (arch, n)
