"""On-line estimation of the interference tail index alpha (paper Remark 3).

The ADOTA update needs alpha both for the |Delta|^alpha accumulator and the
alpha-root stepsize. The paper points to moment-type estimators for
multivariate alpha-stable laws [42]; we implement the classic *log-moment*
estimator (Ma & Nikias, 1995), which is simple, consistent, jit-able and
needs only samples of the interference (e.g. measured on a quiet
sub-carrier between rounds):

For X ~ S(alpha, beta=0, c, 0):

    E[log|X|]   = euler_gamma * (1/alpha - 1) + log c
    Var[log|X|] = (pi^2 / 6) * (1/alpha^2 + 1/2)

so  1/alpha^2 = 6 * Var[log|X|] / pi^2 - 1/2, clipped into alpha in (1, 2].
A Hill-type order-statistics estimator is provided as a cross-check.

**The fused-stats contract (PR 5).** The kernels never materialise the
interference vector for the estimator; instead the ``ota_channel_slab``
/ ``ota_receive_slab`` epilogues reduce the pilot residual r (the
interference actually injected this round) to THREE sufficient
statistics

    stats = [count, sum log|r|, sum log^2|r|]     over entries r != 0

(the zero mask drops the slab's padding tail and the disabled-channel
case for free, and makes the statistics subset-agnostic: any pilot
sub-slice, any shard slice, and the full slab all speak the same
3-vector, which simply psum-adds across shards).
``alpha_from_log_moments`` turns the reduced stats into the same
log-moment estimate ``log_moment_estimate`` computes from raw samples;
``update_alpha_ema`` folds it into the resident across-round EMA
``alpha_hat`` carried by ``SlabTrainState``.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

_EULER = 0.5772156649015329


def log_moment_estimate(samples: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Estimate (alpha, scale) of a symmetric alpha-stable law.

    Args:
      samples: 1-D array of i.i.d. draws (any float dtype).

    Returns:
      (alpha_hat, scale_hat), clipped to alpha in (1.01, 2.0].
    """
    x = jnp.abs(samples.astype(jnp.float32).reshape(-1))
    x = jnp.maximum(x, jnp.finfo(jnp.float32).tiny)
    lx = jnp.log(x)
    mean, var = jnp.mean(lx), jnp.var(lx)
    inv_a2 = jnp.maximum(6.0 * var / (math.pi**2) - 0.5, 1e-6)
    alpha = jnp.clip(1.0 / jnp.sqrt(inv_a2), 1.01, 2.0)
    scale = jnp.exp(mean - _EULER * (1.0 / alpha - 1.0))
    return alpha, scale


def hill_estimate(samples: jax.Array, k_frac: float = 0.05) -> jax.Array:
    """Hill estimator of the tail index from the upper order statistics.

    alpha_hat = k / sum_{i<k} (log X_(i) - log X_(k)) over the k largest
    |samples|. Static ``k = max(8, k_frac * n)``, clamped to ``n - 1`` so
    the ``top_k(x, k + 1)`` order-statistics window always fits (n < 9
    used to raise inside top_k). Degenerate inputs stay finite instead
    of raising: all-equal samples (zero log-spacing denominator) clip to
    the upper bound 4.0 — no tail spread reads as the lightest tail we
    report — and n == 1 (k == 0, no spacings at all) clips to the lower
    bound. Biased for stable laws at moderate n (the stable tail is only
    asymptotically Pareto) — used as a sanity cross-check of the
    log-moment estimator, not in the optimizer.
    """
    x = jnp.abs(samples.astype(jnp.float32).reshape(-1))
    n = x.shape[0]
    k = min(max(8, int(k_frac * n)), n - 1)
    top = jax.lax.top_k(x, k + 1)[0]
    top = jnp.maximum(top, jnp.finfo(jnp.float32).tiny)
    logs = jnp.log(top)
    denom = jnp.sum(logs[:k] - logs[k])
    alpha = k / jnp.maximum(denom, jnp.finfo(jnp.float32).tiny)
    return jnp.clip(alpha, 0.5, 4.0)


# ---------------------------------------------------------------------------
# Fused-epilogue statistics: the closed alpha loop (PR 5).
# ---------------------------------------------------------------------------

def log_moment_stats(residual: jax.Array) -> jax.Array:
    """Reduce a pilot residual to the ``[count, sum log|r|, sum log^2|r|]``
    sufficient statistics over its NONZERO entries.

    This is the jnp mirror of the kernel epilogues' reduction: the
    zero mask excludes the slab padding tail (the CMS fixed point
    (u=0, e=1) synthesizes exactly 0 there) and degenerates to
    ``count == 0`` when the channel injects no interference. Stats from
    disjoint slices (shards, pilot windows, per-leaf draws) ADD, so the
    sharded engine psum-reduces them like the RoundMetrics norms.
    """
    r = jnp.abs(residual.astype(jnp.float32).reshape(-1))
    m = r > 0.0
    logr = jnp.where(m, jnp.log(jnp.maximum(r, jnp.finfo(jnp.float32).tiny)),
                     0.0)
    return jnp.stack([jnp.sum(m.astype(jnp.float32)), jnp.sum(logr),
                      jnp.sum(logr * logr)])


def alpha_from_log_moments(stats: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(alpha_hat, scale_hat) from reduced ``[count, sum log|r|,
    sum log^2|r|]`` statistics — ``log_moment_estimate`` re-expressed on
    the sufficient statistics so the estimate can be formed from the
    kernel epilogues' psum-reduced 3-vector without ever materialising
    the samples. ``count == 0`` (no interference observed) returns the
    (meaningless) upper-clip values; callers gate on ``stats[0]``.
    """
    count = jnp.maximum(stats[0], 1.0)
    mean = stats[1] / count
    var = jnp.maximum(stats[2] / count - mean * mean, 0.0)
    inv_a2 = jnp.maximum(6.0 * var / (math.pi**2) - 0.5, 1e-6)
    alpha = jnp.clip(1.0 / jnp.sqrt(inv_a2), 1.01, 2.0)
    scale = jnp.exp(mean - _EULER * (1.0 / alpha - 1.0))
    return alpha, scale


def update_alpha_ema(alpha_hat: jax.Array, stats: jax.Array,
                     rho: float = 0.1) -> jax.Array:
    """One resident-EMA step of the online tail-index tracker.

    ``alpha_hat`` is the scalar carried across rounds by
    ``SlabTrainState`` with 0.0 as the "not yet seeded" sentinel (alpha
    lives in (1, 2], so 0 is unreachable): the first round with an
    observable residual adopts the raw estimate, later rounds blend with
    weight ``rho``, and rounds with no residual (``stats[0] == 0`` —
    interference disabled) pass the previous value through unchanged.
    The sentinel convention makes the EMA resume-proof: a restored
    checkpoint continues the blend exactly where it stopped.
    """
    est, _ = alpha_from_log_moments(stats)
    blended = jnp.where(alpha_hat > 0.0,
                        (1.0 - rho) * alpha_hat + rho * est, est)
    return jnp.where(stats[0] > 0.0, blended, alpha_hat)


def effective_alpha(alpha_hat: jax.Array) -> jax.Array:
    """The tail index the update rule consumes under tracking: the EMA
    once seeded, else the Gaussian endpoint 2.0 — the principled default
    when no interference has been observed (no heavy tail measured =>
    assume the lightest admissible one; also exactly right for the
    interference-free channel, where the estimator never seeds)."""
    return jnp.where(alpha_hat > 0.0, alpha_hat,
                     jnp.asarray(2.0, jnp.float32))


def estimate_from_gradient_residual(g_clean: jax.Array, g_noisy: jax.Array
                                    ) -> Tuple[jax.Array, jax.Array]:
    """Estimate alpha from the residual of a known-clean reference gradient.

    In deployments where a narrowband pilot round is possible, the server
    can difference a digitally-verified gradient against the OTA one; the
    residual is (approximately) the interference vector.
    """
    return log_moment_estimate((g_noisy - g_clean).reshape(-1))
