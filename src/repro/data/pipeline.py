"""Client-sharded batching for federated rounds.

``FederatedBatcher`` owns the per-client index partitions and yields, for
round t, the stacked per-client batches expected by
``repro.core.fl.make_round_step`` — leaves shaped (N, b, ...) (or
(N, k, b, ...) when local_steps > 1).
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.data.partition import (dirichlet_partition, heterogeneity_index,
                                  iid_partition)
from repro.data.synthetic import ClassificationData


class FederatedBatcher:
    def __init__(self, data: ClassificationData, n_clients: int,
                 batch_size: int, dir_alpha: Optional[float] = 0.1,
                 local_steps: int = 1, seed: int = 0):
        self.data = data
        self.n_clients = n_clients
        self.batch_size = batch_size
        self.local_steps = local_steps
        if dir_alpha is None:
            self.parts = iid_partition(len(data.y), n_clients, seed)
        else:
            # min_per_client=1: the sampler below draws with replacement
            # when a client's shard is smaller than its batch.
            self.parts = dirichlet_partition(data.y, n_clients, dir_alpha,
                                             seed, min_per_client=1)
        self.rng = np.random.default_rng(seed + 1)

    def __call__(self, round_idx: int, key=None) -> Dict[str, np.ndarray]:
        del round_idx, key
        k, b = self.local_steps, self.batch_size
        xs, ys = [], []
        for part in self.parts:
            take = self.rng.choice(part, size=k * b, replace=len(part) < k * b)
            xs.append(self.data.x[take])
            ys.append(self.data.y[take])
        x = np.stack(xs)     # (N, k*b, ...)
        y = np.stack(ys)
        if k > 1:
            x = x.reshape(self.n_clients, k, b, *x.shape[2:])
            y = y.reshape(self.n_clients, k, b)
        return {"x": x, "y": y}

    def heterogeneity(self) -> float:
        return heterogeneity_index(self.parts, self.data.y)
