"""Pytree <-> slab contract: layout, round-trips, padding invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slab import (LANE, make_slab_spec, slab_to_tree, stack_to_slab,
                             tree_to_slab, zeros_slab)


def _mixed_tree(key):
    ks = jax.random.split(key, 4)
    return {
        "emb": jax.random.normal(ks[0], (7, 33), jnp.bfloat16),
        "blocks": [
            {"w": jax.random.normal(ks[1], (130,), jnp.float32),
             "b": jax.random.normal(ks[2], (1,), jnp.float32)},
        ],
        "scale": jax.random.normal(ks[3], ()),   # scalar leaf
    }


def test_spec_layout_static():
    tree = _mixed_tree(jax.random.key(0))
    spec = make_slab_spec(tree)
    assert spec.total == 7 * 33 + 130 + 1 + 1
    assert spec.padded % LANE == 0
    assert spec.padded >= spec.total
    # offsets are contiguous in leaf order
    for i in range(1, spec.n_leaves):
        assert spec.offsets[i] == spec.offsets[i - 1] + spec.sizes[i - 1]


def test_roundtrip_restores_shapes_and_dtypes():
    tree = _mixed_tree(jax.random.key(1))
    spec = make_slab_spec(tree)
    slab = tree_to_slab(spec, tree)
    assert slab.shape == (spec.padded,)
    assert slab.dtype == jnp.float32
    back = slab_to_tree(spec, slab)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    for a, b in jax.tree.leaves(jax.tree.map(lambda x, y: (x, y), tree, back),
                                is_leaf=lambda x: isinstance(x, tuple)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)


def test_roundtrip_nocast_keeps_f32():
    tree = _mixed_tree(jax.random.key(2))
    spec = make_slab_spec(tree)
    back = slab_to_tree(spec, tree_to_slab(spec, tree), cast=False)
    for leaf in jax.tree.leaves(back):
        assert leaf.dtype == jnp.float32


def test_padding_tail_is_zero_and_norm_preserved():
    tree = {"w": jnp.full((3, 5), 2.0)}       # 15 elements -> padded to 128
    spec = make_slab_spec(tree)
    slab = tree_to_slab(spec, tree)
    np.testing.assert_array_equal(np.asarray(slab[spec.total:]), 0.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(slab)),
                               float(jnp.linalg.norm(tree["w"])), rtol=1e-6)


def test_stack_to_slab_matches_per_client_flatten():
    n = 4
    tree = {"a": jnp.arange(n * 6, dtype=jnp.float32).reshape(n, 2, 3),
            "b": jnp.arange(n * 5, dtype=jnp.float32).reshape(n, 5)}
    spec = make_slab_spec({"a": jnp.zeros((2, 3)), "b": jnp.zeros(5)})
    stacked = stack_to_slab(spec, tree)
    assert stacked.shape == (n, spec.padded)
    for c in range(n):
        per_client = tree_to_slab(
            spec, {"a": tree["a"][c], "b": tree["b"][c]})
        np.testing.assert_array_equal(np.asarray(stacked[c]),
                                      np.asarray(per_client))


def test_spec_from_shape_dtype_structs():
    structs = {"w": jax.ShapeDtypeStruct((9, 9), jnp.bfloat16)}
    spec = make_slab_spec(structs)
    assert spec.total == 81 and spec.dtypes[0] == jnp.bfloat16


def test_empty_tree_rejected():
    with pytest.raises(ValueError):
        make_slab_spec({})


def test_zeros_slab():
    spec = make_slab_spec({"w": jnp.ones(200)})
    z = zeros_slab(spec)
    assert z.shape == (spec.padded,) and float(jnp.sum(jnp.abs(z))) == 0.0


def test_roundtrip_inside_jit():
    tree = _mixed_tree(jax.random.key(3))

    @jax.jit
    def f(t):
        spec = make_slab_spec(t)
        return slab_to_tree(spec, tree_to_slab(spec, t) * 2.0)

    out = f(tree)
    np.testing.assert_allclose(
        np.asarray(out["blocks"][0]["w"]),
        np.asarray(tree["blocks"][0]["w"]) * 2.0, rtol=1e-6)
