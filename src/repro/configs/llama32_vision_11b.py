"""Llama-3.2-Vision-11B [hf:meta-llama/Llama-3.2-11B-Vision]: 40L decoder
d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 128256; gated
cross-attention to vision tokens every 5th layer. Vision tower + projector
are a STUB — input spec supplies (B, 1601, 4096) patch embeddings."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=128256, rope_theta=500000.0, cross_attn_period=5,
    n_img_tokens=1601,
    notes="cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision]",
)
