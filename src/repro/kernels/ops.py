"""jit'd public wrappers around the Pallas kernels.

``fused_server_update`` is the production entry point: it routes the
parameter pytree through the slab engine (``repro.core.slab``) and
applies the fused ADOTA update kernel in ONE launch over the whole
model, replacing the ~10-pass jnp expression chain of
``repro.core.adaptive`` with one read-modify-write HBM pass. The jnp
reference implementations remain the default on non-TPU backends; the
kernels run in interpret mode there (tests) and compiled on TPU —
``interpret=None`` defers to ``repro.kernels.interpret`` (platform
auto + the ``REPRO_PALLAS_INTERPRET`` env var), so these entry points
compile on TPU without every caller opting in.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptive import (_SLAB_MODES, AdaptiveConfig, ServerOptState,
                                 apply_slab_update)
from repro.core.slab import make_slab_spec, tree_to_slab
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ota_channel import ota_channel_slab

PyTree = Any

_MODE_TO_OPTIMIZER = {mode: name for name, mode in _SLAB_MODES.items()}


@functools.partial(jax.jit, static_argnames=("lr", "beta1", "beta2", "alpha",
                                             "eps", "mode", "interpret"))
def fused_server_update(g: PyTree, state: ServerOptState, params: PyTree, *,
                        lr: float, beta1: float, beta2: float, alpha: float,
                        eps: float, mode: str = "adam",
                        interpret: Optional[bool] = None
                        ) -> Tuple[PyTree, ServerOptState]:
    """Kernel-fused equivalent of any registered server optimizer's
    .update(): one ``adaptive_update_slab`` launch over the whole model
    slab. ``state`` must come from the matching optimizer's init (e.g.
    the amsgrad mode expects the {"v", "vmax"} nu dict). For
    ``momentum``, ``beta1`` is the server momentum coefficient."""
    if mode not in _MODE_TO_OPTIMIZER:
        raise ValueError(f"unknown update mode {mode!r}; "
                         f"options: {sorted(_MODE_TO_OPTIMIZER)}")
    cfg = AdaptiveConfig(optimizer=_MODE_TO_OPTIMIZER[mode], lr=lr,
                         beta1=beta1, beta2=beta2, alpha=alpha, eps=eps,
                         momentum=beta1, backend="pallas",
                         interpret=interpret)
    spec = make_slab_spec(params)
    return apply_slab_update(cfg, spec, tree_to_slab(spec, g), state, params)


@functools.partial(jax.jit, static_argnames=("alpha", "scale", "interpret"))
def fused_ota_aggregate(grads: jax.Array, h: jax.Array, key: jax.Array, *,
                        alpha: float, scale: float,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Kernel-fused OTA MAC on stacked client gradients (N, d)."""
    d = grads.shape[1]
    ku, ke = jax.random.split(key)
    u = jax.random.uniform(ku, (d,), jnp.float32,
                           -math.pi / 2 + 1e-6, math.pi / 2 - 1e-6)
    e = -jnp.log(jax.random.uniform(ke, (d,), jnp.float32,
                                    minval=jnp.finfo(jnp.float32).tiny))
    return ota_channel_slab(grads, h, u, e, alpha=alpha, scale=scale,
                            interpret=interpret)


causal_flash_attention = jax.jit(
    functools.partial(flash_attention, causal=True),
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
