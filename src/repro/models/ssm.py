"""Selective state-space (Mamba-style) branch used by Hymba's hybrid heads.

Recurrence (per channel c, state n):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D * x_t
with input-dependent dt/B/C ("selective"). Sequence form uses
``jax.lax.associative_scan`` (parallel prefix, O(log S) depth); decode is
a single O(1) state update — which is why the hybrid/SSM architectures
take the long_500k shape natively.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int              # expanded channels (Hymba: ~2x d_model)
    d_state: int = 16
    d_conv: int = 4           # depthwise causal conv width
    dt_rank: int = 0          # 0 -> ceil(d_model / 16)
    chunk: int = 0            # 0 = one associative scan over S; >0 =
                              # sequential scan over S/chunk blocks with an
                              # associative scan inside each (bounds the
                              # (B, S, C, N) f32 working set — §Perf lever)

    @property
    def rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)


def ssm_init(key, cfg: SSMConfig, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # S4D-real initialisation for A.
    a = jnp.tile(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32)[None, :],
                 (cfg.d_inner, 1))
    dt_init = jax.random.uniform(k5, (cfg.d_inner,), jnp.float32,
                                 math.log(1e-3), math.log(1e-1))
    return {
        "in_proj": dense_init(k1, cfg.d_model, (2 * cfg.d_inner,), dtype),
        "conv": (jax.random.normal(k2, (cfg.d_conv, cfg.d_inner), jnp.float32)
                 * (1.0 / math.sqrt(cfg.d_conv))).astype(dtype),
        "conv_bias": jnp.zeros((cfg.d_inner,), dtype),
        "x_proj": dense_init(k3, cfg.d_inner,
                             (cfg.rank + 2 * cfg.d_state,), dtype),
        "dt_proj": dense_init(k4, cfg.rank, (cfg.d_inner,), dtype, use_bias=True),
        "dt_bias": dt_init,                       # softplus^-1-ish floor
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((cfg.d_inner,), jnp.float32),
        "out_proj": dense_init(k6, cfg.d_inner, (cfg.d_model,), dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array
                           ) -> jax.Array:
    """x: (B, S, C), w: (K, C) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    segs = [xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k)]
    return sum(segs) + b[None, None, :]


def _selective_terms(p: dict, cfg: SSMConfig, xc: jax.Array):
    """From conv output xc (..., S, C) derive dt (.., S, C), B/C (.., S, N)."""
    proj = dense(p["x_proj"], xc).astype(jnp.float32)
    dt_lo = proj[..., :cfg.rank]
    b_t = proj[..., cfg.rank:cfg.rank + cfg.d_state]
    c_t = proj[..., cfg.rank + cfg.d_state:]
    dt = jax.nn.softplus(
        dense(p["dt_proj"], dt_lo.astype(xc.dtype)).astype(jnp.float32)
        + p["dt_bias"][None, None, :])
    return dt, b_t, c_t


def ssm_forward(p: dict, cfg: SSMConfig, x: jax.Array,
                return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model), parallel associative scan.
    With ``return_state`` also returns the decode cache after the last
    token (h state + conv window)."""
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(
        _causal_depthwise_conv(xi, p["conv"], p["conv_bias"]).astype(jnp.float32)
    ).astype(x.dtype)
    dt, b_t, c_t = _selective_terms(p, cfg, xc)
    a = -jnp.exp(p["a_log"])                                     # (C, N)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    if cfg.chunk and x.shape[1] > cfg.chunk:
        # Discretise PER CHUNK inside the scan so the (B, chunk, C, N)
        # f32 tensors never materialise over the full sequence — this is
        # what bounds the working set (the full-S version allocates
        # B*S*C*N floats twice).
        b_sz, s_len = x.shape[0], x.shape[1]
        n = -(-s_len // cfg.chunk)
        pad = n * cfg.chunk - s_len
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))) if pad else dt
        bt_p = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0))) if pad else b_t
        ct_p = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0))) if pad else c_t
        xc_p = (jnp.pad(xc.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
                if pad else xc.astype(jnp.float32))
        sp = n * cfg.chunk
        chunked = lambda t: t.reshape(b_sz, n, cfg.chunk, -1).swapaxes(0, 1)
        dt_c, bt_c, ct_c, xc_c = map(chunked, (dt_p, bt_p, ct_p, xc_p))

        def chunk_step(h0, xs):
            dtj, btj, ctj, xcj = xs
            ab = jnp.exp(dtj[..., None] * a[None, None])      # (B,c,C,N)
            bb = (dtj * xcj)[..., None] * btj[..., None, :]
            a_cum, h_local = jax.lax.associative_scan(combine, (ab, bb),
                                                      axis=1)
            h_full = h_local + a_cum * h0[:, None]
            yc = jnp.einsum("bscn,bsn->bsc", h_full, ctj)
            return h_full[:, -1], yc

        h0 = jnp.zeros((b_sz, cfg.d_inner, cfg.d_state), jnp.float32)
        h_last, y_c = jax.lax.scan(chunk_step, h0,
                                   (dt_c, bt_c, ct_c, xc_c))
        y = y_c.swapaxes(0, 1).reshape(b_sz, sp, -1)[:, :s_len]
        y = y + p["d_skip"] * xc.astype(jnp.float32)
    else:
        abar = jnp.exp(dt[..., None] * a[None, None])            # (B,S,C,N)
        bx = (dt * xc.astype(jnp.float32))[..., None] * b_t[..., None, :]
        a_s, h = jax.lax.associative_scan(combine, (abar, bx), axis=1)
        h_last = h[:, -1]
        y = jnp.einsum("bscn,bsn->bsc", h, c_t) \
            + p["d_skip"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = dense(p["out_proj"], y)
    if not return_state:
        return out
    kc = cfg.d_conv - 1
    if x.shape[1] >= kc:
        conv_win = xi[:, -kc:]
    else:
        conv_win = jnp.pad(xi, ((0, 0), (kc - x.shape[1], 0), (0, 0)))
    return out, {"h": h_last, "conv": conv_win}


def init_ssm_cache(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }


def ssm_decode_step(p: dict, cfg: SSMConfig, x: jax.Array, cache: dict
                    ) -> Tuple[jax.Array, dict]:
    """x: (B, 1, d_model); O(1) state update."""
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                            # (B,1,C)
    window = jnp.concatenate([cache["conv"], xi.astype(cache["conv"].dtype)],
                             axis=1)                             # (B,K,C)
    w = p["conv"].astype(jnp.float32)
    xc = jnp.sum(window.astype(jnp.float32) * w[None], axis=1, keepdims=True)
    xc = jax.nn.silu(xc + p["conv_bias"].astype(jnp.float32)[None, None])
    xc = xc.astype(x.dtype)
    dt, b_t, c_t = _selective_terms(p, cfg, xc)                  # (B,1,*)
    a = -jnp.exp(p["a_log"])
    abar = jnp.exp(dt[..., None] * a[None, None])[:, 0]          # (B,C,N)
    bx = ((dt * xc.astype(jnp.float32))[..., None] * b_t[..., None, :])[:, 0]
    h = cache["h"] * abar + bx                                   # (B,C,N)
    y = jnp.einsum("bcn,bn->bc", h, c_t[:, 0]) \
        + p["d_skip"] * xc[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    out = dense(p["out_proj"], y)
    return out, {"h": h, "conv": window[:, 1:]}
