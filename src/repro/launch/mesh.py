"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — jax locks the device count at first
backend init, and only ``dryrun.py`` sets the 512-host-device XLA flag.
"""

from __future__ import annotations

from repro.compat import make_auto_mesh
from repro.core.shard import client_axes_of, n_client_shards


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods).

    Axes: "data" carries the FL clients (one client group per data
    shard), "model" carries tensor/expert parallelism, "pod" is the
    cross-pod data/FSDP axis in the multi-pod deployment.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_client_mesh(shape):
    """Mesh whose EVERY axis carries clients — the sharded slab engine's
    deployment shape (no model parallelism; ``repro.core.shard`` splits
    the slab, not the tensors). 1-D shapes get the canonical ("data",)
    axis, 2-D ("pod", "data"); higher ranks fall back to generic names.

    On a CPU host run under ``--xla_force_host_platform_device_count``
    (see ``launch.train``/``launch.shard_check``) this is how the OTA
    round is simulated multi-device.
    """
    shape = tuple(shape)
    names = {1: ("data",), 2: ("pod", "data")}.get(
        len(shape), tuple(f"clients{i}" for i in range(len(shape))))
    return make_auto_mesh(shape, names)


def data_axes(mesh) -> tuple:
    """The client-carrying axes of a mesh (everything except "model")."""
    return client_axes_of(mesh)


def n_clients_of(mesh) -> int:
    return n_client_shards(mesh)
