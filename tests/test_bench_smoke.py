"""Benchmark-harness smoke: ``benchmarks/run.py --quick`` must run and
write schema-valid JSON under ``--out`` — so the bench harness (and the
BENCH_round_step.json perf trajectory, now including the sharded
backend) cannot silently rot.

Scoped to ``--only round_step``: that is the artifact tracked across
PRs; the paper-figure benches are exercised by their own test modules.
"""

import json
import os
import subprocess
import sys

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

ROUND_STEP_REQUIRED_KEYS = {"name", "backend", "n_params", "n_clients",
                            "us_per_round", "us_per_call", "hbm_bytes_est",
                            "derived"}


def test_quick_bench_writes_valid_round_step_json(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out_dir = str(tmp_path / "bench")
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick",
         "--only", "round_step", "--out", out_dir],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=1200)
    assert res.returncode == 0, res.stdout[-2000:] + res.stderr[-2000:]
    # CSV header + one line per record on stdout
    assert res.stdout.splitlines()[0] == "name,us_per_call,derived"

    bench_path = os.path.join(out_dir, "BENCH_round_step.json")
    assert os.path.exists(bench_path), os.listdir(out_dir)
    with open(bench_path) as f:
        payload = json.load(f)
    # provenance stamp: the perf trajectory must be attributable
    meta = payload["meta"]
    assert set(meta) >= {"git_sha", "date", "config", "config_fingerprint"}
    assert meta["git_sha"] and meta["date"].endswith("Z")
    assert len(meta["config_fingerprint"]) == 16
    assert meta["config"]["quick"] is True
    records = payload["records"]
    assert isinstance(records, list) and records

    by_backend = {}
    for rec in records:
        assert "ERROR" not in rec["name"], rec
        missing = ROUND_STEP_REQUIRED_KEYS - set(rec)
        assert not missing, (rec["name"], missing)
        assert rec["us_per_round"] > 0
        assert rec["hbm_bytes_est"] > 0
        by_backend.setdefault(rec["backend"], []).append(rec)
    # jnp + pallas + the sharded column, >= 2 model sizes each
    assert set(by_backend) == {"jnp", "pallas", "pallas_sharded"}
    for backend, recs in by_backend.items():
        sizes = {r["n_params"] for r in recs}
        assert len(sizes) >= 2, (backend, sizes)
    # the sharded records carry their mesh shape
    assert all("mesh" in r for r in by_backend["pallas_sharded"])

    # the quick run must NOT clobber the tracked repo-root artifact
    # (it writes under --out instead) — guard the path logic.
    with open(os.path.join(REPO_ROOT, "BENCH_round_step.json")) as f:
        json.load(f)   # still valid JSON, untouched by this run


def test_only_rejects_unknown_bench_name(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--quick",
         "--only", "round_stpe", "--out", str(tmp_path / "bench")],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=300)
    assert res.returncode != 0
    assert "unknown bench name 'round_stpe'" in res.stderr
    assert "round_step" in res.stderr   # the valid names are listed
