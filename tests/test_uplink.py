"""The staged uplink pipeline and the int8-quantized OTA MAC.

Contract under test (see ``repro.core.ota`` / ``repro.kernels.ota_channel``):

* ``uplink="f32"`` is the identity pipeline — covered by the existing
  parity suites, which must pass unchanged.
* ``uplink="int8"``: the transmit quantize-on-write epilogue produces
  int8 payloads with per-128-block f32 scales; the per-entry
  dequantization error is bounded by the entry's block scale
  (``blockmax / 127``); stochastic rounding is unbiased; the zero
  padding tail survives the wire exactly; and jnp / pallas /
  pallas_sharded agree under the shared PRNG contract — jnp vs pallas
  to within one quantization step per entry (f32 summation-order
  differences may flip individual rounding decisions), the sharded
  engine to accumulated quantization-error order (per-transmitter
  quantization), with the (1,)-mesh bitwise-equal to the single-device
  pallas engine (exercised via ``shard_check --uplink int8``).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_server, make_round_step,
                        make_slab_spec, ota_aggregate_stacked, ota_psum,
                        uplink_sr_slab_inputs)
from repro.core.slab import stack_to_slab
from repro.kernels.ota_channel import (LANE, ota_receive_slab,
                                       ota_transmit_slab)
from repro.kernels.ref import ota_receive_ref, ota_transmit_ref

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SHAPES = [(3, 45), (130,), (1,), (257,)]
N = 9


def _stacked_grads(key=40, dtype=jnp.float32):
    return {f"p{i}": jax.random.normal(jax.random.key(key + i), (N,) + s,
                                       dtype)
            for i, s in enumerate(SHAPES)}


def _slab_case():
    grads = _stacked_grads()
    spec = make_slab_spec(jax.tree.map(
        lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype), grads))
    gs = stack_to_slab(spec, grads)
    h = jnp.abs(jax.random.normal(jax.random.key(1), (N,))) + 0.5
    r = uplink_sr_slab_inputs(jax.random.key(2), spec)[0]
    return spec, gs, h, r


def test_uplink_config_validation():
    assert UplinkConfig().mode == "f32"
    assert not UplinkConfig().quantized
    assert UplinkConfig(mode="int8").quantized
    with pytest.raises(ValueError):
        UplinkConfig(mode="fp8")
    with pytest.raises(ValueError):
        UplinkConfig(block=64)
    # a bare mode string on the channel config is coerced
    cfg = OTAChannelConfig(uplink="int8")
    assert isinstance(cfg.uplink, UplinkConfig) and cfg.uplink.mode == "int8"
    # and the default leaves existing configs untouched
    assert OTAChannelConfig().uplink == UplinkConfig()


def test_legacy_psum_path_refuses_quantized_uplink():
    """The pre-pipeline per-leaf collective only speaks the analog f32
    wire; a quantized config must refuse loudly, not silently run f32."""
    cfg = OTAChannelConfig(uplink="int8")
    with pytest.raises(NotImplementedError, match="quantized uplink"):
        ota_psum({"w": jnp.ones((4,))}, jax.random.key(0), cfg, ("data",))


def test_quantization_error_bounded_by_block_scale():
    """|dequant(quant(x)) - x| <= the entry's block scale, elementwise
    (stochastic floor moves x/s by < 1)."""
    spec, gs, h, r = _slab_case()
    partial = ota_transmit_ref(gs, h)
    q, s = ota_transmit_ref(gs, h, quantize=True, r=r)
    deq = ota_receive_ref(q[None], s[None], jnp.zeros_like(partial),
                          jnp.ones_like(partial), alpha=1.5, scale=0.0)
    bound = np.repeat(np.asarray(s), LANE)
    err = np.abs(np.asarray(deq) - np.asarray(partial))
    assert np.all(err <= bound * (1 + 1e-6) + 1e-12), float(np.max(err / bound))
    # the error is not trivially zero (quantization actually happened)
    assert float(np.max(err)) > 0


def test_zero_tail_survives_the_wire():
    """The slab's zero padding tail quantizes to payload 0 / scale 1 and
    dequantizes back to exactly 0 — the slab norm contract holds."""
    spec, gs, h, r = _slab_case()
    assert spec.padded > spec.total
    for impl in (ota_transmit_ref, ota_transmit_slab):
        q, s = impl(gs, h, quantize=True, r=r)
        q, s = np.asarray(q), np.asarray(s)
        assert np.all(q[spec.total:] == 0)
        full_blocks = -(-spec.total // LANE)   # tail blocks past all leaves
        assert np.all(s[full_blocks:] == 1.0)


def test_transmit_kernel_matches_ref_within_one_quantum():
    """Kernel vs op-mirrored oracle: scales agree to f32 rounding and
    payloads differ by at most 1 codeword on (rarely) flipped rounding
    decisions."""
    spec, gs, h, r = _slab_case()
    qk, sk = ota_transmit_slab(gs, h, quantize=True, r=r)
    qr, sr = ota_transmit_ref(gs, h, quantize=True, r=r)
    assert qk.dtype == jnp.int8 and sk.shape == (spec.padded // LANE,)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    dq = np.abs(np.asarray(qk, np.int32) - np.asarray(qr, np.int32))
    assert dq.max() <= 1
    assert float(np.mean(dq != 0)) < 0.01


def test_receive_kernel_matches_ref():
    rows, d = 4, 6 * LANE
    q = jax.random.randint(jax.random.key(3), (rows, d), -127, 128,
                           dtype=jnp.int8)
    s = jnp.abs(jax.random.normal(jax.random.key(4), (rows, d // LANE))) + 0.1
    u = jax.random.uniform(jax.random.key(5), (d,), minval=-1.5, maxval=1.5)
    e = jnp.abs(jax.random.normal(jax.random.key(6), (d,))) + 0.1
    out_k = ota_receive_slab(q, s, u, e, alpha=1.5, scale=0.3)
    out_r = ota_receive_ref(q, s, u, e, alpha=1.5, scale=0.3)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_stochastic_rounding_is_unbiased():
    """E[dequant] == x over the rounding draws (the transmit epilogue's
    floor(x/s + r) with r ~ U[0,1) is unbiased)."""
    d = 2 * LANE
    x = jax.random.normal(jax.random.key(8), (1, d))
    reps = 400
    acc = np.zeros((d,), np.float64)
    for k in range(reps):
        r = jax.random.uniform(jax.random.key(1000 + k), (d,))
        q, s = ota_transmit_ref(x, jnp.ones((1,)), quantize=True, r=r)
        acc += np.repeat(np.asarray(s), LANE) * np.asarray(q, np.float64)
    mean = acc / reps
    scale = np.repeat(np.asarray(
        ota_transmit_ref(x, jnp.ones((1,)), quantize=True,
                         r=jnp.zeros((d,)))[1]), LANE)
    # SE of the mean of U(-s/2-ish, s/2-ish) errors ~ s / sqrt(12 reps)
    tol = 5.0 * scale / np.sqrt(12 * reps)
    assert np.all(np.abs(mean - np.asarray(x[0], np.float64)) <= tol)


def test_deterministic_rounding_mode():
    """stochastic_rounding=False rounds to nearest and needs no draws."""
    spec, gs, h, _ = _slab_case()
    qk, sk = ota_transmit_slab(gs, h, quantize=True, stochastic=False)
    qr, sr = ota_transmit_ref(gs, h, quantize=True, stochastic=False)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    assert np.abs(np.asarray(qk, np.int32)
                  - np.asarray(qr, np.int32)).max() <= 1
    cfg = OTAChannelConfig(
        alpha=1.5, xi_scale=0.1,
        uplink=UplinkConfig(mode="int8", stochastic_rounding=False))
    g1, _ = ota_aggregate_stacked(jax.random.key(0), cfg, _stacked_grads())
    g2, _ = ota_aggregate_stacked(jax.random.key(0), cfg, _stacked_grads())
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("interference", [True, False])
def test_int8_aggregate_error_bound_jnp_and_pallas(interference):
    """Backend-level acceptance: against the f32 slab aggregate with the
    SAME draws, the int8 uplink's error is the transmit quantization
    error — bounded per entry by its block scale — on both single-device
    backends."""
    grads = _stacked_grads()
    key = jax.random.key(7)
    cfg = OTAChannelConfig(alpha=1.5, xi_scale=0.2, interference=interference,
                           backend="pallas")
    c8 = dataclasses.replace(cfg, uplink=UplinkConfig(mode="int8"))
    g_f32, _ = ota_aggregate_stacked(key, cfg, grads)

    spec, gs, h, r = None, None, None, None
    outs = {}
    for backend in ("jnp", "pallas"):
        g8, h8 = ota_aggregate_stacked(
            key, dataclasses.replace(c8, backend=backend), grads)
        outs[backend] = g8
        # recompute the per-block scales this aggregate used
        spec = make_slab_spec(jax.tree.map(
            lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype), grads))
        gs = stack_to_slab(spec, grads)
        _, s = ota_transmit_ref(gs, h8, quantize=True,
                                r=uplink_sr_slab_inputs(key, spec)[0])
        bound = np.repeat(np.asarray(s), LANE)
        flat8 = np.concatenate([np.asarray(x).ravel()
                                for x in jax.tree.leaves(g8)])
        flat32 = np.concatenate([np.asarray(x).ravel()
                                 for x in jax.tree.leaves(g_f32)])
        err = np.abs(flat8 - flat32)
        # + a few ulps of the result: the heavy-tail interference term
        # can dwarf the payload, and f32/int8 add it in separate ops.
        slack = 4 * np.spacing(np.abs(flat32, dtype=np.float32))
        assert np.all(err <= bound[:spec.total] * (1 + 1e-5) + slack + 1e-7), \
            backend

    # jnp vs pallas: same draws, same layout -> within one quantum/entry
    for a, b in zip(jax.tree.leaves(outs["jnp"]), jax.tree.leaves(outs["pallas"])):
        a, b = np.asarray(a), np.asarray(b)
        assert np.max(np.abs(a - b)) <= float(np.max(np.asarray(s))) + 1e-6


def test_round_step_int8_jnp_pallas_close():
    """A full adam_ota round over the quantized MAC: jnp and pallas land
    within (lr-scaled) quantization-step distance."""
    params = {f"p{i}": jax.random.normal(jax.random.key(2 + i), s)
              for i, s in enumerate(SHAPES)}

    def loss_fn(p, batch):
        return sum(jnp.mean((x - b) ** 2)
                   for x, b in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))

    n = 6
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (n,) + p.shape), params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          uplink=UplinkConfig(mode="int8"))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)
    outs = {}
    for backend in ("jnp", "pallas"):
        rs = make_round_step(loss_fn, ch, ad, fl, backend=backend)
        p, s = params, init_server(params, ad)
        for t in range(2):
            p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(9), t),
                         batches)
        outs[backend] = (p, s, m)
    p_j, s_j, m_j = outs["jnp"]
    p_p, s_p, m_p = outs["pallas"]
    for a, b in zip(jax.tree.leaves(p_j), jax.tree.leaves(p_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3,
                                   rtol=1e-4)
    np.testing.assert_allclose(float(m_j.loss), float(m_p.loss), rtol=1e-6)


def test_adam_ota_convergence_preserved_under_int8():
    """The headline capability: adam_ota still converges when the MAC
    carries the quantized payload (examples/quantized_uplink.py is the
    full-size version of this check)."""
    from repro.data import FederatedBatcher, gaussian_mixture
    from repro.models.vision import logistic_regression

    n_clients = 10
    data = gaussian_mixture(1500, 16, 4, seed=0)
    model = logistic_regression(16, 4)
    batcher = FederatedBatcher(data, n_clients, 16, dir_alpha=0.5)
    fl = FLConfig(n_clients=n_clients)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)

    def batch_fn(t):
        b = batcher(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    finals = {}
    for mode in ("f32", "int8"):
        ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                              uplink=UplinkConfig(mode=mode))
        rs = make_round_step(model.loss_fn, ch, ad, fl)
        p = model.init(jax.random.key(0))
        s = init_server(p, ad)
        losses = []
        for t in range(30):
            p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(1), t),
                         batch_fn(t))
            losses.append(float(m.loss))
        finals[mode] = (losses[0], np.mean(losses[-5:]))
    for mode, (first, last) in finals.items():
        assert last < 0.7 * first, (mode, first, last)
    # quantization must not visibly hurt the optimisation (doing better
    # is fine — the rounding noise is tiny next to the channel noise)
    assert finals["int8"][1] <= 1.5 * finals["f32"][1] + 1e-3, finals


def test_int8_multi_device_acceptance():
    """shard_check --uplink int8 on 8 forced host devices: jnp int8
    oracle vs resident pallas (near-exact), meshes (1,)/(2,)/(4,2)
    within accumulated quantization error, bitwise rerun determinism."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check", "--uplink",
         "int8", "--optimizers", "adam_ota", "fedavg", "--rounds", "3",
         "--meshes", "1", "2", "4,2"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=1200)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-2000:]
    assert "PARITY OK" in res.stdout
