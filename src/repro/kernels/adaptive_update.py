"""Fused ADOTA server-update Pallas kernel — all six server optimizers.

The ADOTA update (Eq. 8-11) is elementwise over every parameter:

    Delta <- b1*Delta + (1-b1)*g
    v     <- f(v, |Delta|^a)          (mode-dependent, see below)
    w     <- w - lr * Delta / (v+eps)^{1/a}

Naively chained in jnp this is ~10 HBM round-trips over 4 model-sized
arrays; the fractional |.|^a and (.)^{1/a} powers (exp/log on the VPU)
make it strictly memory-bound. The kernel performs the whole update in
ONE read-modify-write pass per block: each grid step streams a
(block_rows, 128) tile of the operands HBM->VMEM, does all the math in
VMEM/VREGs, and writes the outputs back.

Modes (matching ``repro.core.adaptive`` update rules exactly):

    adagrad   v += |Delta|^a                       (AdaGrad-OTA, Eq. 9)
    adam      v = b2 v + (1-b2)|Delta|^a           (Adam-OTA,    Eq. 10)
    amsgrad   adam v, plus vmax = max(vmax, v); step divides by vmax
    yogi      v -= (1-b2) sign(v - |Delta|^a)|Delta|^a
    momentum  Delta = b1 Delta + g; w -= lr Delta  (FedAvgM; no v)
    sgd       w -= lr g                            (FedAvg; stateless)

The operand list varies with the mode (sgd needs no state, amsgrad
carries an extra vmax slab); ``adaptive_update_slab`` assembles the
right ``pallas_call`` and always returns ``(*updated_state, w')`` in
(delta, nu, nu_max) order — 3-tuple for adagrad/adam/yogi, 4-tuple for
amsgrad, 2-tuple for momentum, 1-tuple for sgd.

TPU is the target (bf16/f32 tiles aligned to the 8x128 VPU lanes); on
this CPU container the kernel body is validated with interpret=True
against ``ref.adaptive_update_ref``. The elementwise math mirrors the
jnp reference ops exactly (same |.|** / zero-fill / maximum guards), so
interpret-mode results match the tree.map path to f32 rounding.

Sharded slab engine (``repro.core.shard``): the update is elementwise,
so each mesh device passes its OWN contiguous slab slice here and the
grid covers just that shard — P devices each run one launch of 1/P the
size instead of one device running the full-model launch. Slices are
valid operands by construction: the shard-aligned padding rule
(``make_slab_spec(..., shards=P)``) makes every slice lane-aligned, and
the zero tail stays a fixed point of all six modes (delta' = b1*0, nu
update of 0 is 0, w' = 0 - lr*0/denom = 0), so regathered slices equal
the unsharded result exactly.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The single source of the |.|^alpha zero-guard: the jnp/pallas backend
# parity contract depends on the kernel computing the exact same ops as
# the tree.map reference. (Import is cycle-safe: core.adaptive pulls in
# this module only lazily, inside apply_slab_update.)
from repro.core.adaptive import _abs_pow
from repro.kernels.interpret import (INTERPRET_BLOCK_CAP, coarse_block,
                                     resolve_interpret)

LANE = 128
DEFAULT_BLOCK_ROWS = 256     # (256, 128) f32 tile = 128 KiB per operand

MODES = ("adagrad", "adam", "amsgrad", "yogi", "momentum", "sgd")


def _adaptive_update_kernel(*refs, lr: float, beta1: float, beta2: float,
                            alpha, eps: float, mode: str):
    # alpha is either a static python float (baked into the kernel — the
    # alpha="static" fast path, bitwise-identical to the pre-runtime-
    # alpha code) or None, meaning the closed-loop tracked value arrives
    # as the FIRST operand: a (1, 1) f32 block replicated to every grid
    # step. Only the alpha-power family reads it.
    if alpha is None:
        alpha = refs[0][0, 0]
        refs = refs[1:]
    g = refs[0][...].astype(jnp.float32)
    if mode == "sgd":
        w_ref, w_out = refs[1:]
        w_out[...] = (w_ref[...].astype(jnp.float32) - lr * g).astype(
            w_out.dtype)
        return

    delta_ref = refs[1]
    gain = 1.0 if mode == "momentum" else (1.0 - beta1)
    delta = beta1 * delta_ref[...] + gain * g

    if mode == "momentum":
        w_ref, delta_out, w_out = refs[2:]
        delta_out[...] = delta
        w_out[...] = (w_ref[...].astype(jnp.float32) - lr * delta).astype(
            w_out.dtype)
        return

    da = _abs_pow(delta, alpha)
    if mode == "amsgrad":
        nu_ref, vmax_ref, w_ref, delta_out, nu_out, vmax_out, w_out = refs[2:]
        nu = beta2 * nu_ref[...] + (1.0 - beta2) * da
        vmax = jnp.maximum(vmax_ref[...], nu)
        vmax_out[...] = vmax
        denom_v = vmax
    else:
        nu_ref, w_ref, delta_out, nu_out, w_out = refs[2:]
        if mode == "adagrad":
            nu = nu_ref[...] + da
        elif mode == "adam":
            nu = beta2 * nu_ref[...] + (1.0 - beta2) * da
        else:  # yogi
            v = nu_ref[...]
            nu = v - (1.0 - beta2) * jnp.sign(v - da) * da
        denom_v = nu
    denom = jnp.maximum(denom_v + eps, 0.0) ** (1.0 / alpha)
    w = w_ref[...].astype(jnp.float32) - lr * delta / denom
    delta_out[...] = delta
    nu_out[...] = nu
    w_out[...] = w.astype(w_out.dtype)


def adaptive_update_slab(g: jax.Array, delta: Optional[jax.Array],
                         nu: Optional[jax.Array], w: jax.Array, *, lr: float,
                         beta1: float, beta2: float, alpha, eps: float,
                         mode: str, nu_max: Optional[jax.Array] = None,
                         block_rows: int = DEFAULT_BLOCK_ROWS,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jax.Array, ...]:
    """Fused server update on a 1-D parameter slab (any length; padded to
    lanes internally).

    g/w may be bf16 or f32; delta/nu/nu_max are f32 state (ignored — pass
    None — for modes that do not carry them). For ``momentum``, ``beta1``
    is the server momentum coefficient (g enters with gain 1). Returns
    the updated slabs in ``(delta', nu', nu_max', w')`` order, dropping
    the entries the mode does not own; ``w'`` is always last.

    ``alpha`` may be a static python float (baked into the kernel — the
    historical path, bitwise-unchanged) or a traced f32 scalar (a
    ``jax.Array``): the closed-loop tracked tail index. A traced alpha
    rides in as one extra (1, 1) operand broadcast to every grid step,
    so changing the estimate between rounds re-runs, not re-compiles,
    the kernel. Modes outside the alpha-power family (momentum/sgd)
    never read alpha and always take the static path.
    """
    if mode not in MODES:
        raise ValueError(f"unknown update mode {mode!r}; options: {MODES}")
    interpret = resolve_interpret(interpret)
    traced_alpha = (isinstance(alpha, jax.Array)
                    and mode in ("adagrad", "adam", "amsgrad", "yogi"))
    n = g.shape[0]
    rows = -(-n // LANE)
    # Interpret-mode grid coarsening (cap in rows: cap * LANE elements
    # per interpreted step; the update is elementwise, so any tiling of
    # the row axis is bitwise-equivalent).
    block_rows = coarse_block(rows, block_rows, interpret,
                              cap=INTERPRET_BLOCK_CAP // LANE)
    rows_pad = -(-rows // block_rows) * block_rows
    total = rows_pad * LANE

    def shape2d(x, dt=None):
        x = jnp.pad(x, (0, total - n))
        return x.reshape(rows_pad, LANE).astype(dt or x.dtype)

    ins = [shape2d(g)]
    n_state = 0
    if mode != "sgd":
        ins.append(shape2d(delta, jnp.float32))
        n_state += 1
    if mode in ("adagrad", "adam", "amsgrad", "yogi"):
        ins.append(shape2d(nu, jnp.float32))
        n_state += 1
    if mode == "amsgrad":
        ins.append(shape2d(nu_max, jnp.float32))
        n_state += 1
    ins.append(shape2d(w))

    grid = (rows_pad // block_rows,)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    in_specs = [blk] * len(ins)
    if traced_alpha:
        ins.insert(0, jnp.asarray(alpha, jnp.float32).reshape(1, 1))
        in_specs.insert(0, pl.BlockSpec((1, 1), lambda i: (0, 0)))
    kernel = functools.partial(
        _adaptive_update_kernel, lr=lr, beta1=beta1, beta2=beta2,
        alpha=None if traced_alpha else alpha, eps=eps, mode=mode)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[blk] * (n_state + 1),
        out_shape=[jax.ShapeDtypeStruct((rows_pad, LANE), jnp.float32)
                   ] * n_state
        + [jax.ShapeDtypeStruct((rows_pad, LANE), w.dtype)],
        interpret=interpret,
    )(*ins)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]
    unpad = lambda x2: x2.reshape(-1)[:n]
    return tuple(unpad(o) for o in outs)
