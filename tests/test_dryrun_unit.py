"""Dry-run machinery units (no 512-device flag needed here): HLO
collective parsing, shape adjustment, optimizers/configs wiring."""


from repro.launch.dryrun import _shape_bytes, collective_bytes
from repro.launch.specs import (INPUT_SHAPES, LONG_CONTEXT_WINDOW,
                                cache_length, shape_config)
from repro.configs import get_config


def test_shape_bytes():
    assert _shape_bytes("f32[128,4]{1,0}") == 2048
    assert _shape_bytes("bf16[10]{0}") == 20
    assert _shape_bytes("(f32[4]{0}, u32[2]{0})") == 24
    assert _shape_bytes("pred[]") == 1   # scalar -> 1 elem
    assert _shape_bytes("token[]") == 0  # unknown type skipped


def test_collective_parse():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), dims={0}
  %ar.1 = f32[512]{0} all-reduce(f32[512]{0} %y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[512]{0} %y), dimensions={0}
  %a2a = (f32[8]{0}, f32[8]{0}) all-to-all(f32[8]{0} %a, f32[8]{0} %b)
  %cp-start = bf16[64]{0} collective-permute-start(bf16[64]{0} %z)
  %other = f32[99]{0} add(f32[99]{0} %p, f32[99]{0} %q)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 512 * 4
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 64
    assert out["collective-permute"] == 128


def test_input_shapes_match_assignment():
    assert INPUT_SHAPES["train_4k"] == dict(seq=4096, batch=256, kind="train")
    assert INPUT_SHAPES["prefill_32k"] == dict(seq=32768, batch=32,
                                               kind="prefill")
    assert INPUT_SHAPES["decode_32k"] == dict(seq=32768, batch=128,
                                              kind="decode")
    assert INPUT_SHAPES["long_500k"] == dict(seq=524288, batch=1,
                                             kind="decode")


def test_long_context_gets_window():
    dense = get_config("qwen3-14b")
    assert dense.window is None
    adj = shape_config(dense, "long_500k")
    assert adj.window == LONG_CONTEXT_WINDOW
    # native-window arch keeps its own window
    sc = get_config("starcoder2-15b")
    assert shape_config(sc, "long_500k").window == 4096
    # rwkv needs no window (O(1) state)
    rw = get_config("rwkv6-7b")
    assert shape_config(rw, "long_500k").window is None
    # other shapes untouched
    assert shape_config(dense, "train_4k").window is None


def test_cache_length_respects_window():
    sc = get_config("starcoder2-15b")        # window 4096
    assert cache_length(sc, 524288) == 4096
    assert cache_length(sc, 1024) == 1024
    q = get_config("qwen3-14b")
    assert cache_length(q, 32768) == 32768


def test_mesh_constructors_pure():
    """Importing mesh.py must not initialise jax devices."""
    import importlib
    import repro.launch.mesh as m
    importlib.reload(m)   # would fail if module-level device usage existed
    assert callable(m.make_production_mesh)
