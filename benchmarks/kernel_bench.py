"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python
loop — not meaningful to time), so the timed quantity is the jnp
REFERENCE path under jit (the algorithmic cost the kernel removes), plus
the derived HBM-traffic model showing the fusion win the kernel delivers
on TPU:

    naive chain  : ~9 model-sized HBM transfers per ADOTA update
    fused kernel : 4 reads + 3 writes in ONE pass (= 7 transfers),
                   and no intermediate materialisation.
"""

from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step)
from repro.kernels.interpret import INTERPRET_ENV, resolve_interpret
from repro.kernels.ref import (adaptive_update_ref, flash_attention_ref,
                               ota_channel_ref)


def _time(fn, *args, iters=20) -> float:
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def bench_adaptive_update(n: int = 1 << 20) -> Dict:
    ks = jax.random.split(jax.random.key(0), 4)
    g = jax.random.normal(ks[0], (n,))
    d = jax.random.normal(ks[1], (n,))
    v = jnp.abs(jax.random.normal(ks[2], (n,)))
    w = jax.random.normal(ks[3], (n,))
    f = jax.jit(lambda *a: adaptive_update_ref(
        *a, lr=0.01, beta1=0.9, beta2=0.3, alpha=1.5, eps=1e-8, mode="adam"))
    us = _time(f, g, d, v, w)
    hbm_bytes_fused = 7 * 4 * n          # 4 reads + 3 writes, f32
    return dict(name="adaptive_update_ref_1M", us_per_call=us,
                derived=f"fused_hbm_bytes={hbm_bytes_fused}")


def bench_ota_channel(n_clients: int = 32, d: int = 1 << 18) -> Dict:
    ks = jax.random.split(jax.random.key(0), 4)
    G = jax.random.normal(ks[0], (n_clients, d))
    h = jax.random.uniform(ks[1], (n_clients,))
    u = jax.random.uniform(ks[2], (d,), minval=-1.5, maxval=1.5)
    e = -jnp.log(jax.random.uniform(ks[3], (d,), minval=1e-6))
    f = jax.jit(lambda *a: ota_channel_ref(*a, alpha=1.5, scale=0.1))
    us = _time(f, G, h, u, e)
    return dict(name=f"ota_channel_ref_{n_clients}x{d}", us_per_call=us,
                derived=f"grad_bytes={4 * n_clients * d}")


def bench_attention(s: int = 1024) -> Dict:
    q = jax.random.normal(jax.random.key(0), (1, s, 8, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(1), (1, s, 2, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(2), (1, s, 2, 64), jnp.bfloat16)
    f = jax.jit(lambda *a: flash_attention_ref(*a, causal=True))
    us = _time(f, q, k, v, iters=5)
    flops = 4 * s * s * 8 * 64
    return dict(name=f"attention_ref_s{s}", us_per_call=us,
                derived=f"flops={flops}")


def _round_step_case(n_params: int, n_clients: int):
    """A multi-leaf quadratic model of ~n_params total parameters."""
    a = n_params // 2
    b = n_params // 4
    shapes = {"w1": (a,), "w2": (b // 2, 2), "b": (n_params - a - 2 * (b // 2),)}
    ks = jax.random.split(jax.random.key(0), len(shapes))
    params = {k: jax.random.normal(kk, s)
              for (k, s), kk in zip(shapes.items(), ks)}

    def loss_fn(p, batch):
        return sum(jnp.mean((x - t) ** 2)
                   for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))

    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(1), (n_clients,) + p.shape),
        params)
    return params, loss_fn, batches


def bench_round_step(n_params: int, n_clients: int = 8,
                     iters: int = 5) -> List[Dict]:
    """One full ADOTA round, jnp tree.map backend vs the pallas slab
    engine (interpret mode on CPU — the pallas wall time here measures
    the Python interpreter loop, NOT the TPU kernel; the bytes-moved
    model is the hardware-relevant comparison). Records both backends so
    the perf trajectory is tracked from PR 1 on."""
    params, loss_fn, batches = _round_step_case(n_params, n_clients)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.02, alpha=1.5)
    fl = FLConfig(n_clients=n_clients)
    # HBM-traffic model, f32 words: the MAC reads (N+1)d and writes d
    # either way; the server update is 4 reads + 3 writes fused vs ~10
    # model-sized transfers as a chained jnp expression.
    bytes_mac = 4 * n_params * (n_clients + 2)
    records = []
    for backend, upd_transfers in (("jnp", 10), ("pallas", 7)):
        rs = make_round_step(loss_fn, ch, ad, fl, backend=backend)
        state = init_server(params, ad)
        key = jax.random.key(2)
        run = lambda: rs(params, state, key, batches)
        jax.block_until_ready(run())         # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = run()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / iters * 1e6
        records.append(dict(
            name=f"round_step_{backend}_{n_params}",
            backend=backend, n_params=n_params, n_clients=n_clients,
            interpret={"resolved": resolve_interpret(None),
                       "env": os.environ.get(INTERPRET_ENV)},
            us_per_round=us, us_per_call=us,
            hbm_bytes_est=bytes_mac + upd_transfers * 4 * n_params,
            derived=f"hbm_bytes_est={bytes_mac + upd_transfers * 4 * n_params}",
        ))
    return records


def all_benches() -> List[Dict]:
    return [bench_adaptive_update(), bench_ota_channel(), bench_attention()]
