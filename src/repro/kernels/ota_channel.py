"""Fused OTA-channel Pallas kernel: fading-scaled client-gradient
reduction + Chambers-Mallows-Stuck alpha-stable interference, one pass.

    out[d] = (1/N) * sum_n h[n] * G[n, d] + scale * CMS(u[d], e[d]; alpha)

In the OTA simulator this is the server-side "RF front end": N stacked
client gradients are combined under per-client fading and the heavy-tail
interference is synthesized in the same VMEM tile (uniform angles u and
Exp(1) draws e are produced upstream by the TPU PRNG; the CMS transform
itself is branch-free VPU math: sin/cos/pow). Memory-bound in G — the
kernel reads each gradient element exactly once.

The CMS math is ``repro.core.channel.cms_transform`` — the same guarded
expression the jnp sampler uses, so kernel and reference agree bitwise
in interpret mode: angles are clipped strictly inside (-pi/2, pi/2)
(endpoint angles made the old log-space form NaN, even at alpha == 2
where the transform reduces to the finite Gaussian 2*sin(u)*sqrt(e))
and the Exp(1) draws are floored. The tail index is validated against
the same (1, 2] range as ``OTAChannelConfig``.

Grid: 1-D over column blocks of size (N, block_cols); the N reduction
runs inside the tile (N = clients-per-shard is small, <= a few hundred).

Sharded slab engine: when the round is distributed over a device mesh
(``repro.core.shard``), each device launches this kernel on its LOCAL
client shard only, passing ``n_total`` = the global client count so the
1/N normalisation matches the single-device launch; the cross-device
``psum`` then completes the superposition (the mesh is the multiple-
access channel). The grid covers just the local rows/columns, so the
launch cost scales down with the shard, not the model.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.channel import cms_transform

LANE = 128
DEFAULT_BLOCK_COLS = 512


def _ota_kernel(g_ref, h_ref, u_ref, e_ref, out_ref, *, alpha: float,
                scale: float, n_clients: int):
    g = g_ref[...].astype(jnp.float32)              # (N, bc)
    h = h_ref[...].astype(jnp.float32)              # (N, 1)
    agg = jnp.sum(h * g, axis=0, keepdims=True) / n_clients   # (1, bc)
    xi = cms_transform(u_ref[...], e_ref[...], alpha)         # (1, bc)
    out_ref[...] = agg + scale * xi


def ota_channel_slab(grads: jax.Array, h: jax.Array, u: jax.Array,
                     e: jax.Array, *, alpha: float, scale: float,
                     n_total: int | None = None,
                     block_cols: int = DEFAULT_BLOCK_COLS,
                     interpret: bool = True) -> jax.Array:
    """grads: (N, d) stacked client gradients; h: (N,) fading draws;
    u: (d,) uniform angles in (-pi/2, pi/2); e: (d,) Exp(1) draws.
    Returns the aggregated noisy gradient (d,) float32.

    ``n_total`` overrides the 1/N normalisation (defaults to the local
    row count N). The sharded engine passes the GLOBAL client count here
    while feeding only this shard's rows, so per-shard partial sums psum
    to exactly the single-device aggregate."""
    if not (1.0 < alpha <= 2.0):
        raise ValueError(f"tail index alpha must be in (1, 2], got {alpha}")
    n, d = grads.shape
    if n_total is None:
        n_total = n
    d_pad = -(-d // block_cols) * block_cols
    gp = jnp.pad(grads, ((0, 0), (0, d_pad - d)))
    up = jnp.pad(u, (0, d_pad - d)).reshape(1, d_pad)
    ep = jnp.pad(e, (0, d_pad - d), constant_values=1.0).reshape(1, d_pad)
    h2 = h.reshape(n, 1).astype(jnp.float32)

    grid = (d_pad // block_cols,)
    out = pl.pallas_call(
        functools.partial(_ota_kernel, alpha=alpha, scale=scale,
                          n_clients=n_total),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, block_cols), lambda i: (0, i)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
            pl.BlockSpec((1, block_cols), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_cols), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
        interpret=interpret,
    )(gp, h2, up, ep)
    return out.reshape(-1)[:d]
