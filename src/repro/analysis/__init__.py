"""repro-lint: repo-specific static analysis for the slab engine.

Nine PRs of growth left the engine with a web of invariants that lived
only in docstrings and reviewer memory — the PRNG draw contract
(identical draws sliced, never re-keyed, across the jnp / pallas /
pallas_sharded backends), the slab zero-padding tail surviving every
kernel mode and wire format, the kernel <-> jnp-oracle mirror in
``repro.kernels.ref``, and the donated-buffer discipline of the
compiled fast path. This package machine-enforces them:

* **AST tier** (``repro.analysis.ast_rules``) — pure-stdlib rules over
  ``src/``: the fold_in domain-separator ledger
  (``repro.analysis.fold_registry``), re-keying inside round bodies,
  quantized aggregates paired with ``restore_zero_tail``, every public
  Pallas kernel mirrored by a signature-matching oracle, and module
  import hygiene. Runs anywhere Python runs; no jax needed.
* **jaxpr tier** (``repro.analysis.jaxpr_checks``) — abstractly traces
  ``make_slab_round_step`` per backend on a tiny config cell and
  asserts the PRNG-consumption ledger is identical across backends,
  that the all-f32 wire cell contains no precision downcast, and that
  every donated ``SlabTrainState`` byte is aliased by the compiled
  round scan.

Run ``python -m repro.analysis`` (add ``--jaxpr`` for the second
tier). Accepted findings live in the committed baseline
(``.repro-lint-baseline.json``); CI fails only on NEW findings. A
finding can also be waived in place with a trailing
``# repro-lint: allow[<rule-id>]`` comment (``lazy-import`` is the
dedicated waiver for deliberate function-local imports).
"""

from repro.analysis.findings import (DEFAULT_BASELINE, Finding,
                                     load_baseline, new_findings,
                                     write_baseline)
from repro.analysis.fold_registry import MIN_SEPARATOR, REGISTERED_FOLDS
from repro.analysis.ast_rules import (AST_RULES, analyze_paths,
                                      analyze_repo)

__all__ = [
    "AST_RULES", "DEFAULT_BASELINE", "Finding", "MIN_SEPARATOR",
    "REGISTERED_FOLDS", "analyze_paths", "analyze_repo", "load_baseline",
    "new_findings", "write_baseline",
]
