"""jaxpr-tier repro-lint: contracts checked on real traces.

The AST tier reads source; this tier traces ``make_slab_round_step``
on a tiny config cell per backend and inspects the jaxprs (recursing
into pjit / scan / cond / shard_map / pallas_call subjaxprs):

* ``prng-ledger`` — the multiset of random-bit-generating equations
  (primitive name + output shapes) must be IDENTICAL across the jnp,
  pallas and pallas_sharded backends. This is the identical-draw
  contract stated structurally: a backend that draws more, fewer, or
  differently-shaped randomness has forked the streams even if a
  seed-level numeric test happens to pass.
* ``wire-downcast`` — the all-f32 wire cell (no uplink/downlink
  quantization configured) must contain ZERO
  ``convert_element_type`` equations to int8/uint8/bf16/f16: the f32
  master update path never narrows outside a declared wire boundary.
* ``post-donation-use`` — with ``donate=True`` every byte of the
  donated ``SlabTrainState`` must be input-output aliased by the
  compiled round scan (via ``repro.core.fl.donation_report``); an
  unaliased donated buffer means something still reads it after
  donation, silently forcing a copy.

Heavier than the AST tier (imports jax, traces the engine) — run via
``python -m repro.analysis --jaxpr``. Findings anchor to
``src/repro/core/fl.py`` (the round-step builder that owns these
contracts) with the backend name as the stable baseline snippet.
"""

from __future__ import annotations

from collections import Counter
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding
from repro.compat import make_auto_mesh
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        donation_report, init_train_state,
                        make_slab_round_runner, make_slab_round_step)

JAXPR_RULES = {
    "prng-ledger":
        "PRNG-consumption equations differ across round-step backends",
    "wire-downcast":
        "precision downcast in the all-f32 cell outside a wire boundary",
    "post-donation-use":
        "donated state bytes not fully aliased by the compiled scan",
    "jaxpr-internal-error":
        "a jaxpr-tier check itself crashed (API drift?)",
}

# Contracts live in the round-step builder; jaxpr findings anchor there.
_ANCHOR = "src/repro/core/fl.py"

_RANDOM_PRIMS = ("random_bits", "threefry2x32")
_WIRE_DTYPES = ("int8", "uint8", "bfloat16", "float16")


def _jaxprs_in(value):
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _jaxprs_in(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _jaxprs_in(v)


def _walk_eqns(closed_jaxpr):
    """Every equation, recursing through all subjaxpr-bearing params.

    No visited-set: two pjit eqns can share one cached subjaxpr object
    (jax memoises traced wrappers like ``jax.random.uniform``) yet
    represent two executions — deduping by identity would undercount
    the draws.
    """
    stack = [closed_jaxpr.jaxpr]
    while stack:
        jaxpr = stack.pop()
        for eqn in jaxpr.eqns:
            yield eqn
            for v in eqn.params.values():
                stack.extend(_jaxprs_in(v))


def prng_ledger(fn, *args) -> Counter:
    """Multiset of (primitive, output shapes) for random-bit eqns."""
    closed = jax.make_jaxpr(fn)(*args)
    counts: Counter = Counter()
    for eqn in _walk_eqns(closed):
        if eqn.primitive.name in _RANDOM_PRIMS:
            shapes = tuple(tuple(v.aval.shape) for v in eqn.outvars)
            counts[(eqn.primitive.name, shapes)] += 1
    return counts


def downcast_ledger(fn, *args) -> Counter:
    """Multiset of banned convert_element_type target dtypes."""
    closed = jax.make_jaxpr(fn)(*args)
    counts: Counter = Counter()
    for eqn in _walk_eqns(closed):
        if eqn.primitive.name != "convert_element_type":
            continue
        dtype = str(eqn.params.get("new_dtype"))
        if dtype in _WIRE_DTYPES:
            counts[dtype] += 1
    return counts


def _tiny_cell(backend: str, mesh=None, shards: int = 1):
    """A minimal f32 round cell: step(state, key, batches) traceable.

    Mirrors the test-suite fixture style — two clients, two leaves
    (one with a partial final 128-lane block), the adam_ota cell.
    """
    params = {"a": jnp.ones((3, 5), jnp.float32),
              "b": jnp.ones((130,), jnp.float32)}

    def loss_fn(p, batch):
        return sum(jnp.mean((x - t) ** 2)
                   for x, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(batch)))

    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5,
                        beta2=0.3)
    fl = FLConfig(n_clients=2)
    step = make_slab_round_step(loss_fn, ch, ad, fl, jit=False,
                                backend=backend, mesh=mesh)
    state = init_train_state(ad, params, shards=shards)
    key = jax.random.key(0)
    batches = jax.tree.map(lambda p: jnp.zeros((2,) + p.shape), params)
    return step, state, key, batches


def _backend_cells():
    """(backend-name, cell) for every backend traceable here."""
    cells = [("jnp", _tiny_cell("jnp")),
             ("pallas", _tiny_cell("pallas"))]
    mesh = make_auto_mesh((1,), ("data",))
    cells.append(("pallas_sharded",
                  _tiny_cell("pallas_sharded", mesh=mesh, shards=1)))
    return cells


def check_prng_ledger() -> List[Finding]:
    ledgers: Dict[str, Counter] = {}
    for name, (step, state, key, batches) in _backend_cells():
        ledgers[name] = prng_ledger(step, state, key, batches)
    ref = ledgers["jnp"]
    findings = []
    for name, led in ledgers.items():
        if name == "jnp" or led == ref:
            continue
        diffs = []
        for entry in sorted(set(ref) | set(led), key=repr):
            if ref[entry] != led[entry]:
                prim, shapes = entry
                diffs.append(f"{prim}{list(shapes)}: jnp x{ref[entry]} "
                             f"vs {name} x{led[entry]}")
        findings.append(Finding(
            _ANCHOR, 1, "prng-ledger", "error",
            f"PRNG-consumption ledger differs between jnp and {name} "
            "round steps on the tiny f32 cell: " + "; ".join(diffs),
            snippet=name))
    return findings


def check_wire_downcast() -> List[Finding]:
    findings = []
    for name, (step, state, key, batches) in _backend_cells():
        counts = downcast_ledger(step, state, key, batches)
        if counts:
            detail = ", ".join(f"{d} x{n}"
                               for d, n in sorted(counts.items()))
            findings.append(Finding(
                _ANCHOR, 1, "wire-downcast", "error",
                f"{name} round step on the all-f32 cell downcasts the "
                f"master path ({detail}) — narrowing is only allowed "
                "inside declared wire boundaries (quantized cells)",
                snippet=name))
    return findings


def check_donation() -> List[Finding]:
    params = {"a": jnp.ones((3, 5), jnp.float32),
              "b": jnp.ones((130,), jnp.float32)}

    def loss_fn(p, batch):
        return sum(jnp.mean((x - t) ** 2)
                   for x, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(batch)))

    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5,
                        beta2=0.3)
    fl = FLConfig(n_clients=2)
    run = make_slab_round_runner(loss_fn, ch, ad, fl, donate=True)
    state = init_train_state(ad, params)
    keys = jnp.stack([jax.random.key(3), jax.random.key(4)])
    batches = jax.tree.map(lambda p: jnp.zeros((2, 2) + p.shape), params)
    rep = donation_report(run, state, keys, batches)
    if not rep["supported"]:
        # This backend's compiled memory analysis does not expose
        # aliasing; nothing to assert (matches the test suite's skip).
        return []
    if rep["aliased_bytes"] != rep["donated_bytes"]:
        return [Finding(
            _ANCHOR, 1, "post-donation-use", "error",
            f"only {rep['aliased_bytes']} of {rep['donated_bytes']} "
            "donated SlabTrainState bytes are input-output aliased by "
            "the compiled round scan — a donated buffer is still "
            "referenced after donation (copy reintroduced)",
            snippet="donate=True")]
    return []


def run_jaxpr_checks() -> List[Finding]:
    """All jaxpr-tier checks; a crashing check surfaces as a finding."""
    findings: List[Finding] = []
    for check in (check_prng_ledger, check_wire_downcast,
                  check_donation):
        try:
            findings += check()
        except Exception as exc:  # noqa: BLE001 - surfaced, not hidden
            findings.append(Finding(
                _ANCHOR, 1, "jaxpr-internal-error", "error",
                f"{check.__name__} crashed: {type(exc).__name__}: {exc}",
                snippet=check.__name__))
    return findings
