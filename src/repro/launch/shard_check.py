"""Multi-round trajectory parity check of the slab-resident engine (CLI).

Runs R full ADOTA rounds three ways and reports the maximum end-of-
trajectory deviation of params / optimizer state / metrics:

* the per-round jnp pytree reference (``make_round_step``, Python loop);
* the slab-RESIDENT single-device pallas loop (``make_slab_round_runner``,
  one ``jax.lax.scan`` over the ``SlabTrainState``);
* the slab-resident ``pallas_sharded`` loop on one or more client-mesh
  shapes (scan *inside* ``shard_map`` — each device carries only its
  slab slices; no full-model regather in the scanned body).

The surviving pytree-per-round API (``make_round_step(
backend="pallas_sharded")``, now a boundary wrapper over the resident
body) is also exercised on every mesh for a subset of optimizers that
covers every state-slab row count (0/1/2/3), so the pack -> resident
round -> unpack boundary keeps real multi-device coverage.

Also asserts seeded determinism: the sharded trajectory run twice with
the same keys must be bitwise equal.

This is the executable form of the resident-engine acceptance contract
(all three loops consume identical PRNG draws and differ only by f32
summation order); tests/test_shard_roundstep.py runs it as a subprocess
so the main pytest process keeps its real single-device view.

``--uplink int8`` runs the same trajectories over the QUANTIZED MAC:
the per-round jnp reference becomes the int8 oracle (op-mirrored ref
kernels), the (1,)-mesh must stay bitwise-equal to the resident pallas
engine, reruns must stay bitwise, and P > 1 meshes — which quantize per
transmitter — must agree to accumulated quantization-error order
(loose tol; the tight single-round error bounds live in
tests/test_uplink.py).

``--track-alpha`` closes the alpha loop (``AdaptiveConfig.alpha =
"auto"``): every engine estimates the interference tail index online
from the fused pilot statistics and feeds the resident EMA back into
the update. The reference becomes the slab-resident jnp loop (the
pytree-per-round API carries no resident alpha_hat and refuses "auto"),
the per-round wrapper rows are skipped for the same reason, and the
end-of-trajectory ``alpha_hat`` deviation joins the parity columns.

    PYTHONPATH=src python -m repro.launch.shard_check \
        --meshes 1 2 4,2 --rounds 5 --tol 1e-5
    PYTHONPATH=src python -m repro.launch.shard_check \
        --uplink int8 --meshes 1 2 4,2 --rounds 5

``--client-chunk`` / ``--sample-rate`` exercise the STREAMED client
axis (PR 6): every engine runs its dynamic round body (chunked
accumulating transmit, Bernoulli participation keyed off the round
key). The reference becomes the slab-resident jnp loop — the
pytree-per-round API carries no streamed uplink path, so those rows
are skipped, exactly like --track-alpha.

``--uplink sign`` / ``--error-feedback`` / ``--downlink int8`` fill
the wire-format matrix (PR 7): 1-bit signSGD payloads, the resident
per-transmitter EF slab riding the scan carry, and the int8-quantized
model broadcast. All three make the slab-resident jnp loop the oracle
(the pytree API refuses them), and the quantized tiers use the loose
quantization-error tolerance.

``--comm-buckets`` switches the pallas_sharded rows to the OVERLAPPED
round (PR 9): the uplink exchange splits into B slab buckets of
psum_scatter, the scalar metrics fuse into one stacked psum, and the
downlink all_gather for round t+1 is issued at the end of round t's
body. References keep the default single-collective round, so the
parity columns measure the bucketed engine against today's graph —
a TOLERANCE tier on f32 (default 1e-4: bucketed summation order plus
the fast-exp CMS transform), still bitwise on rerun determinism.

The XLA flag below MUST precede any jax import (jax locks the device
count at first backend init); at least ``--host-devices`` /
``$REPRO_HOST_DEVICES`` (default 8) host devices are forced, or the
largest --meshes product if bigger (read from raw argv — argparse
would come too late).
"""

import sys

from repro.launch.hostdev import (force_host_devices, mesh_device_count,
                                  positive_int)

force_host_devices(mesh_device_count(sys.argv, "--meshes"))

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_server, init_train_state,
                        make_round_step, make_slab_round_runner,
                        unpack_train_state)
from repro.launch.mesh import make_client_mesh

ALL_OPTIMIZERS = ["adagrad_ota", "adam_ota", "amsgrad_ota", "yogi_ota",
                  "fedavgm", "fedavg"]

# One optimizer per state-slab row count (3/2/1/0): enough to cover
# every pack/unpack shape of the pytree-per-round boundary wrapper.
PERROUND_OPTIMIZERS = ("amsgrad_ota", "adam_ota", "fedavgm", "fedavg")


def _max_dev(a, b) -> float:
    assert jax.tree.structure(a) == jax.tree.structure(b), (
        jax.tree.structure(a), jax.tree.structure(b))
    dev = 0.0
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        denom = np.maximum(np.abs(x), 1.0)
        dev = max(dev, float(np.max(np.abs(x - y) / denom)))
    return dev


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _round_keys(rounds: int):
    return jnp.stack([jax.random.fold_in(jax.random.key(7), t)
                      for t in range(rounds)])


def _run_ref(params, batches, ch, ad, fl, rounds: int):
    """Per-round jnp pytree reference trajectory."""
    rs = make_round_step(_loss_fn, ch, ad, fl, backend="jnp")
    p, s = params, init_server(params, ad)
    for t in range(rounds):
        p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(7), t), batches)
    return p, s, m


def _run_perround(mesh, params, batches, ch, ad, fl, rounds: int):
    """Pytree-per-round API trajectory (the PR-2-compatible boundary
    wrapper) — full pytrees in and out every round."""
    rs = make_round_step(_loss_fn, ch, ad, fl, backend="pallas_sharded",
                         mesh=mesh)
    p, s = params, init_server(params, ad)
    for t in range(rounds):
        p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(7), t), batches)
    return p, s, m


def _run_resident(backend, mesh, n_shards, params, batches, ch, ad, fl,
                  rounds: int):
    """Slab-resident trajectory: one scanned dispatch over R rounds."""
    run = make_slab_round_runner(_loss_fn, ch, ad, fl, backend=backend,
                                 mesh=mesh)
    state = init_train_state(ad, params, shards=n_shards,
                             error_feedback=ch.uplink.error_feedback)
    stacked = jax.tree.map(lambda b: jnp.stack([b] * rounds), batches)
    state, ms = run(state, _round_keys(rounds), stacked)
    p, s = unpack_train_state(ad, state)
    m_last = jax.tree.map(lambda x: x[-1], ms)
    return p, s, m_last


def _devs(ref, out, tol, track_alpha=False):
    (p_ref, s_ref, m_ref), (p, s, m) = ref, out
    devs = {
        "params": _max_dev(p_ref, p),
        "delta": _max_dev(s_ref.delta, s.delta),
        "nu": _max_dev(s_ref.nu, s.nu),
        "loss": abs(float(m_ref.loss) - float(m.loss)),
        "|g_t|": abs(float(m_ref.noisy_grad_norm)
                     - float(m.noisy_grad_norm))
        / max(abs(float(m_ref.noisy_grad_norm)), 1.0),
    }
    if track_alpha:
        devs["a^"] = abs(float(m_ref.alpha_hat) - float(m.alpha_hat))
    return devs, max(devs.values()) <= tol


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", nargs="+", default=["1", "2", "4,2"],
                    help="client-mesh shapes, e.g. --meshes 1 2 4,2")
    ap.add_argument("--optimizers", nargs="+", default=ALL_OPTIMIZERS)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--client-chunk", type=positive_int, default=None,
                    help="stream the client axis in chunks of this many "
                         "rows (per device on sharded meshes); must "
                         "divide the per-device client count")
    ap.add_argument("--sample-rate", type=float, default=1.0,
                    help="per-round Bernoulli participation probability "
                         "(< 1 activates partial participation)")
    ap.add_argument("--host-devices", type=positive_int,
                    default=None,
                    help="minimum forced host device count (consumed "
                         "from raw argv before jax import; also "
                         "settable via $REPRO_HOST_DEVICES)")
    ap.add_argument("--rounds", type=positive_int, default=5)
    ap.add_argument("--uplink", default="f32",
                    choices=["f32", "int8", "sign"],
                    help="MAC payload format under test. f32 is the "
                         "f32-rounding parity contract (tol ~1e-5). "
                         "int8/sign compare the quantized engines against "
                         "the jnp oracle: the (1,)-mesh and the resident "
                         "pallas engine consume identical draws (near-"
                         "exact), while P > 1 meshes quantize per "
                         "transmitter and agree only to accumulated "
                         "quantization-error order — pass a loose --tol "
                         "(e.g. 0.25) for those")
    ap.add_argument("--downlink", default="f32", choices=["f32", "int8"],
                    help="model-broadcast format under test; int8 makes "
                         "the slab-resident jnp loop the oracle (the "
                         "pytree API has no slab broadcast to quantize)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry per-transmitter error feedback (needs a "
                         "quantized --uplink); the slab-resident jnp loop "
                         "becomes the oracle and the resident EF slab "
                         "rides the scan carry on every engine")
    ap.add_argument("--track-alpha", action="store_true",
                    help="run every trajectory with the closed alpha "
                         "loop (AdaptiveConfig.alpha='auto'): fused "
                         "pilot statistics -> resident EMA -> traced "
                         "alpha operand; the reference becomes the "
                         "slab-resident jnp loop and the alpha_hat "
                         "deviation joins the parity columns")
    ap.add_argument("--comm-buckets", type=positive_int, default=1,
                    help="bucket the sharded MAC exchange into this many "
                         "slab buckets (the overlapped round, PR 9): the "
                         "pallas_sharded rows switch to bucketed "
                         "psum_scatter + fused metrics psum + prefetched "
                         "broadcast while every reference stays on the "
                         "default engine; > 1 loosens the default f32 "
                         "tol to 1e-4 (bucketed reassociation + fast-exp "
                         "CMS transform are a tolerance tier)")
    ap.add_argument("--tol", type=float, default=None,
                    help="max relative end-of-trajectory deviation "
                         "(default 1e-5 for --uplink f32, 0.25 for int8)")
    args = ap.parse_args(argv)
    if args.error_feedback and args.uplink == "f32":
        ap.error("--error-feedback needs a quantized uplink "
                 "(--uplink int8 or sign)")
    if args.tol is None:
        if args.uplink == "f32" and args.downlink == "f32":
            args.tol = 1e-4 if args.comm_buckets > 1 else 1e-5
        else:
            args.tol = 0.25

    if args.comm_buckets > 1:
        # The bucketed engine needs the per-shard LANE-block count
        # divisible by B on every mesh under test: 4096 elements give
        # 32/16/8/4 blocks on 1/2/4/8 shards — divisible by 2 and 4.
        params = {
            "emb": jax.random.normal(jax.random.key(0), (16, 128)),
            "w": jax.random.normal(jax.random.key(1), (2047,)),
            "b": jax.random.normal(jax.random.key(2), (1,)),
        }
    else:
        params = {
            "emb": jax.random.normal(jax.random.key(0), (7, 33)),
            "w": jax.random.normal(jax.random.key(1), (257,)),
            "b": jax.random.normal(jax.random.key(2), (1,)),
        }
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3),
                                    (args.clients,) + p.shape), params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          uplink=UplinkConfig(
                              mode=args.uplink,
                              error_feedback=args.error_feedback),
                          downlink=args.downlink)
    fl = FLConfig(n_clients=args.clients, client_chunk=args.client_chunk,
                  sample_rate=args.sample_rate)

    print(f"uplink={args.uplink} downlink={args.downlink} "
          f"ef={args.error_feedback} track_alpha={args.track_alpha} "
          f"chunk={args.client_chunk} sample_rate={args.sample_rate:g} "
          f"comm_buckets={args.comm_buckets} "
          f"rounds={args.rounds} tol={args.tol:g}")
    # Only the sharded rows run the overlap engine; the references keep
    # the default (comm_buckets=1) round so the check measures the
    # bucketed engine against today's graph.
    ch_mesh = (dataclasses.replace(ch, comm_buckets=args.comm_buckets)
               if args.comm_buckets > 1 else ch)
    # Streamed / sampled rounds — and the EF / quantized-downlink wire
    # formats — only exist on the slab-resident engines: the oracle
    # becomes the slab-resident jnp loop and the pytree-per-round rows
    # are skipped, exactly like --track-alpha.
    slab_ref = (args.track_alpha or fl.dynamic_round
                or args.error_feedback or args.downlink != "f32")
    failures = 0
    for opt in args.optimizers:
        ad = AdaptiveConfig(optimizer=opt, lr=0.05,
                            alpha="auto" if args.track_alpha else 1.5,
                            beta2=0.3)
        if slab_ref:
            # The pytree-per-round API refuses alpha="auto" (no resident
            # EMA) and dynamic rounds (no streamed uplink); the oracle
            # is the slab-resident jnp loop.
            ref = _run_resident("jnp", None, 1, params, batches, ch, ad,
                                fl, args.rounds)
        else:
            ref = _run_ref(params, batches, ch, ad, fl, args.rounds)
        out = _run_resident("pallas", None, 1, params, batches, ch, ad, fl,
                            args.rounds)
        devs, ok = _devs(ref, out, args.tol, args.track_alpha)
        failures += not ok
        print(f"{opt:12s} resident pallas   "
              + " ".join(f"{k}={v:.2e}" for k, v in devs.items())
              + ("  OK" if ok else "  FAIL"))
        for mesh_str in args.meshes:
            shape = tuple(int(x) for x in mesh_str.split(","))
            mesh = make_client_mesh(shape)
            n_shards = int(np.prod(shape))
            out = _run_resident("pallas_sharded", mesh, n_shards, params,
                                batches, ch_mesh, ad, fl, args.rounds)
            devs, ok = _devs(ref, out, args.tol, args.track_alpha)
            failures += not ok
            print(f"{opt:12s} resident mesh={mesh_str:5s} "
                  + " ".join(f"{k}={v:.2e}" for k, v in devs.items())
                  + ("  OK" if ok else "  FAIL"))
            if opt in PERROUND_OPTIMIZERS and not slab_ref:
                out_pr = _run_perround(mesh, params, batches, ch_mesh, ad,
                                       fl, args.rounds)
                devs, ok = _devs(ref, out_pr, args.tol)
                failures += not ok
                print(f"{opt:12s} perround mesh={mesh_str:5s} "
                      + " ".join(f"{k}={v:.2e}" for k, v in devs.items())
                      + ("  OK" if ok else "  FAIL"))
            # Seeded determinism: the identical trajectory must be
            # bitwise equal on rerun.
            out2 = _run_resident("pallas_sharded", mesh, n_shards, params,
                                 batches, ch_mesh, ad, fl, args.rounds)
            for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
                if not np.array_equal(np.asarray(x), np.asarray(y)):
                    print(f"{opt:12s} resident mesh={mesh_str}: "
                          "NONDETERMINISTIC rerun")
                    failures += 1
                    break

    print("PARITY OK" if failures == 0 else f"PARITY FAIL ({failures})")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
