"""Model configuration, end-to-end assembly, and sharding rules.

``build_model(cfg)`` returns a ``Model`` whose members cover all four
lowered programs of the dry-run matrix:

    loss_fn(params, batch, weights)  — training loss (OTA-faded weights)
    forward(params, batch)           — full-sequence logits
    prefill(params, batch)           — logits + decode caches
    decode_step(params, cache, token, pos) — one-token serve step

Params are nested dicts; repeated layers are stacked on a leading axis
and scanned. ``partition_spec(cfg, params, mesh_axes)`` assigns
PartitionSpecs by parameter name + shape (Megatron-style tensor
parallelism over the "model" axis, optional FSDP over "data").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.attention import AttentionConfig
from repro.models.layers import (dense, dense_init, embed, embed_init,
                                 sinusoidal_embed, softmax_xent)
from repro.models.mla import MLAConfig
from repro.models.moe import MoEConfig
from repro.models.rwkv import RWKVConfig
from repro.models.ssm import SSMConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                     # dense | mla | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    norm: str = "rmsnorm"
    mlp: str = "swiglu"
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None    # sliding-window attention (ring cache)
    kv_chunk: Optional[int] = None  # online-softmax KV chunking (perf lever)
    window_block: bool = False      # block-local window attention (perf)
    remat: bool = True
    scan_unroll: bool = False       # unroll layer scans (cost calibration)
    param_dtype: str = "bfloat16"
    # MLA
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_sharded: bool = False       # shard_map expert-parallel path (perf)
    # SSM / hybrid
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_chunk: int = 0
    # RWKV
    rwkv_lora_rank: int = 64
    rwkv_chunk: int = 64
    # enc-dec (audio) / vlm stubs
    n_enc_layers: int = 0
    enc_seq: int = 1500             # whisper frame embeddings (stub input)
    cross_attn_period: int = 0      # vlm: 1 cross layer every k layers
    n_img_tokens: int = 1601        # vlm patch embeddings (stub input)
    n_meta_tokens: int = 0          # hymba learnable meta tokens
    notes: str = ""

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_config(self) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.resolved_head_dim,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta, window=self.window,
            kv_chunk=self.kv_chunk, window_block=self.window_block)

    def mla_config(self) -> MLAConfig:
        return MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            q_lora_rank=self.q_lora_rank, kv_lora_rank=self.kv_lora_rank,
            qk_nope_head_dim=self.qk_nope_head_dim,
            qk_rope_head_dim=self.qk_rope_head_dim,
            v_head_dim=self.v_head_dim, rope_theta=self.rope_theta)

    def moe_config(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model, n_experts=self.n_experts, top_k=self.top_k,
            d_ff=self.d_ff, n_shared_experts=self.n_shared_experts,
            capacity_factor=self.capacity_factor, sharded=self.moe_sharded)

    def ssm_config(self) -> SSMConfig:
        return SSMConfig(d_model=self.d_model,
                         d_inner=self.ssm_expand * self.d_model,
                         d_state=self.ssm_state, chunk=self.ssm_chunk)

    def rwkv_config(self) -> RWKVConfig:
        return RWKVConfig(d_model=self.d_model, n_heads=self.n_heads,
                          d_ff=self.d_ff, lora_rank=self.rwkv_lora_rank,
                          chunk=self.rwkv_chunk)

    def n_params(self) -> int:
        """Exact parameter count by eval_shape (no allocation)."""
        model = build_model(self)
        shapes = jax.eval_shape(model.init, jax.random.key(0))
        return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        total = self.n_params()
        expert_block = 3 * self.d_model * self.d_ff   # gate/up/down per expert
        moe_total = self.n_layers * self.n_experts * expert_block
        moe_active = self.n_layers * self.top_k * expert_block
        return total - moe_total + moe_active


class Model(NamedTuple):
    config: ModelConfig
    init: Callable
    forward: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def _block_fns(cfg: ModelConfig):
    if cfg.family in ("dense",):
        return tfm.dense_block(cfg)
    if cfg.family == "mla":
        return tfm.mla_block(cfg)
    if cfg.family == "moe":
        return tfm.moe_block(cfg)
    if cfg.family == "rwkv":
        return tfm.rwkv_block(cfg)
    if cfg.family == "hybrid":
        return tfm.hybrid_block(cfg)
    if cfg.family == "vlm":
        return tfm.vlm_group(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    b_init, b_fwd, b_decode, b_cache, b_pfl = _block_fns(cfg)
    n_stack = (cfg.n_layers // cfg.cross_attn_period
               if cfg.family == "vlm" else cfg.n_layers)
    needs_img = cfg.family == "vlm"

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "embed": embed_init(k1, cfg.vocab, cfg.d_model, cfg.dtype),
            "blocks": tfm.stack_init(b_init, k2, n_stack),
            "final_norm": tfm._norm_init(cfg.norm, cfg.d_model),
            "unembed": dense_init(k3, cfg.d_model, (cfg.vocab,), cfg.dtype),
        }
        if cfg.n_meta_tokens:
            p["meta_tokens"] = (jax.random.normal(
                k4, (cfg.n_meta_tokens, cfg.d_model), jnp.float32)
                * 0.02).astype(cfg.dtype)
        return p

    def _backbone(params, x, img=None):
        aux0 = jnp.zeros((), jnp.float32)
        if needs_img:
            fwd = lambda lp, h: b_fwd(lp, h, img)
        else:
            fwd = b_fwd
        x, aux = tfm.stack_apply(fwd, params["blocks"], x, aux0,
                                 remat=cfg.remat, unroll=cfg.scan_unroll)
        return tfm._norm(cfg.norm, params["final_norm"], x), aux

    def forward(params, batch):
        x = embed(params["embed"], batch["tokens"], cfg.dtype)
        if cfg.n_meta_tokens:
            meta = jnp.broadcast_to(
                params["meta_tokens"][None],
                (x.shape[0],) + params["meta_tokens"].shape).astype(x.dtype)
            x = jnp.concatenate([meta, x], axis=1)
        img = batch.get("image_embed") if needs_img else None
        x, aux = _backbone(params, x, img)
        if cfg.n_meta_tokens:
            x = x[:, cfg.n_meta_tokens:]
        logits = dense(params["unembed"], x)
        return logits, aux

    def loss_fn(params, batch, weights=None):
        logits, aux = forward(params, batch)
        loss = softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], weights,
                            batch.get("mask"))
        return loss + aux

    def init_cache(batch_size, length):
        one = b_cache(batch_size, length)
        cache = {"layers": jax.tree.map(lambda a: jnp.stack([a] * n_stack),
                                        one)}
        if needs_img:
            cache["image_embed"] = jnp.zeros(
                (batch_size, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
        return cache

    def prefill(params, batch, length=None):
        """Forward over the prompt, collecting per-layer decode caches via
        the scan's per-layer outputs. Returns (logits, cache)."""
        tokens = batch["tokens"]
        length = length or tokens.shape[1]
        x = embed(params["embed"], tokens, cfg.dtype)
        if cfg.n_meta_tokens:
            meta = jnp.broadcast_to(
                params["meta_tokens"][None],
                (x.shape[0],) + params["meta_tokens"].shape).astype(x.dtype)
            x = jnp.concatenate([meta, x], axis=1)
        img = batch.get("image_embed") if needs_img else None
        if needs_img:
            fn = lambda lp, xx: b_pfl(lp, xx, length, img)
        else:
            fn = lambda lp, xx: b_pfl(lp, xx, length)
        x, layers = tfm.stack_prefill(fn, params["blocks"], x,
                                      unroll=cfg.scan_unroll)
        if cfg.n_meta_tokens:
            x = x[:, cfg.n_meta_tokens:]
        x = tfm._norm(cfg.norm, params["final_norm"], x)
        logits = dense(params["unembed"], x[:, -1:])
        cache = {"layers": layers}
        if needs_img:
            cache["image_embed"] = (img if img is not None else
                                    jnp.zeros((tokens.shape[0],
                                               cfg.n_img_tokens,
                                               cfg.d_model), cfg.dtype))
        return logits, cache

    def decode_step(params, cache, token, pos):
        """token: (B, 1) int32; pos: scalar int32 absolute TEXT position
        (meta-token offset, if any, is applied internally)."""
        x = embed(params["embed"], token, cfg.dtype)
        img = cache.get("image_embed") if needs_img else None
        if cfg.n_meta_tokens:
            pos = pos + cfg.n_meta_tokens
        if needs_img:
            fn = lambda lp, ch, xx: b_decode(lp, ch, xx, pos, img)
        else:
            fn = lambda lp, ch, xx: b_decode(lp, ch, xx, pos)
        x, new_layers = tfm.stack_decode(fn, params["blocks"],
                                         cache["layers"], x,
                                         unroll=cfg.scan_unroll)
        x = tfm._norm(cfg.norm, params["final_norm"], x)
        logits = dense(params["unembed"], x)
        new_cache = {**cache, "layers": new_layers}
        return logits, new_cache

    return Model(cfg, init, forward, loss_fn, prefill, decode_step, init_cache)


def _build_encdec(cfg: ModelConfig) -> Model:
    ((e_init, e_fwd),
     (d_init, d_fwd, d_decode, d_cache, d_pfl)) = tfm.encdec_blocks(cfg)
    n_enc = cfg.n_enc_layers or cfg.n_layers

    def init(key):
        # Positions are sinusoidal (computed on the fly): Whisper's learned
        # decoder table caps at 448 tokens; the 32k/500k serving shapes need
        # unbounded positions, so we substitute the standard sin/cos
        # embedding (documented in DESIGN.md §8).
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": embed_init(k1, cfg.vocab, cfg.d_model, cfg.dtype),
            "enc_blocks": tfm.stack_init(e_init, k2, n_enc),
            "enc_norm": tfm._norm_init(cfg.norm, cfg.d_model),
            "dec_blocks": tfm.stack_init(d_init, k3, cfg.n_layers),
            "final_norm": tfm._norm_init(cfg.norm, cfg.d_model),
            "unembed": dense_init(k4, cfg.d_model, (cfg.vocab,), cfg.dtype),
        }

    def encode(params, audio_embed):
        x, _ = tfm.stack_apply(e_fwd, params["enc_blocks"],
                               audio_embed.astype(cfg.dtype),
                               jnp.zeros((), jnp.float32), remat=cfg.remat,
                               unroll=cfg.scan_unroll)
        return tfm._norm(cfg.norm, params["enc_norm"], x)

    def forward(params, batch):
        enc = encode(params, batch["audio_embed"])
        tok = batch["tokens"]
        pe = sinusoidal_embed(jnp.arange(tok.shape[1]), cfg.d_model)
        x = embed(params["embed"], tok, cfg.dtype) + pe[None].astype(cfg.dtype)
        fwd = lambda lp, h: d_fwd(lp, h, enc)
        x, aux = tfm.stack_apply(fwd, params["dec_blocks"], x,
                                 jnp.zeros((), jnp.float32), remat=cfg.remat,
                                 unroll=cfg.scan_unroll)
        x = tfm._norm(cfg.norm, params["final_norm"], x)
        return dense(params["unembed"], x), aux

    def loss_fn(params, batch, weights=None):
        logits, aux = forward(params, batch)
        loss = softmax_xent(logits[:, :-1], batch["tokens"][:, 1:], weights,
                            batch.get("mask"))
        return loss + aux

    def init_cache(batch_size, length):
        one = d_cache(batch_size, length)
        return {
            "layers": jax.tree.map(lambda a: jnp.stack([a] * cfg.n_layers), one),
            "enc_out": jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model),
                                 cfg.dtype),
        }

    def decode_step(params, cache, token, pos):
        pe = sinusoidal_embed(jnp.asarray(pos)[None], cfg.d_model)
        x = embed(params["embed"], token, cfg.dtype) + pe[None].astype(cfg.dtype)
        enc = cache["enc_out"]
        fn = lambda lp, ch, xx: d_decode(lp, ch, xx, pos, enc)
        x, new_layers = tfm.stack_decode(fn, params["dec_blocks"],
                                         cache["layers"], x,
                                         unroll=cfg.scan_unroll)
        x = tfm._norm(cfg.norm, params["final_norm"], x)
        logits = dense(params["unembed"], x)
        return logits, {**cache, "layers": new_layers}

    def prefill(params, batch, length=None):
        enc = encode(params, batch["audio_embed"])
        tok = batch["tokens"]
        length = length or tok.shape[1]
        pe = sinusoidal_embed(jnp.arange(tok.shape[1]), cfg.d_model)
        x = embed(params["embed"], tok, cfg.dtype) + pe[None].astype(cfg.dtype)
        fn = lambda lp, xx: d_pfl(lp, xx, length, enc)
        x, layers = tfm.stack_prefill(fn, params["dec_blocks"], x,
                                      unroll=cfg.scan_unroll)
        x = tfm._norm(cfg.norm, params["final_norm"], x)
        logits = dense(params["unembed"], x[:, -1:])
        return logits, {"layers": layers, "enc_out": enc}

    return Model(cfg, init, forward, loss_fn, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# Sharding rules.
# --------------------------------------------------------------------------

# (name-fragment, callable(shape, axes) -> PartitionSpec). First match wins.
# Shapes are WITHOUT the stacked layer axis (it is stripped/prepended).
def _spec_rules(model_axis: str, msize: int, ctr_heads: bool = False):
    def headsharded(shape):
        # (d, H, hd) or (lora, H, hd): shard H if divisible. Otherwise:
        # for DECODE (ctr_heads=True) shard the contraction (d_model)
        # dim — the resulting activation all-reduce is a single token,
        # vastly cheaper than replicating the projection weights
        # (e.g. qwen2.5: 40 heads % 16 != 0). For TRAIN the all-reduce
        # would be (B, S, H, hd) per layer, so weights stay replicated.
        if len(shape) >= 2 and shape[-2] % msize == 0:
            return P(*([None] * (len(shape) - 2)), model_axis, None)
        if ctr_heads and shape[0] % msize == 0:
            return P(model_axis, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    def last_dim(shape):
        if shape[-1] % msize == 0:
            return P(*([None] * (len(shape) - 1)), model_axis)
        return P(*([None] * len(shape)))

    def first_dim(shape):
        if shape[0] % msize == 0:
            return P(model_axis, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    def replicated(shape):
        return P(*([None] * len(shape)))

    return [
        # embeddings / unembed: shard the d_model / vocab column dim.
        ("embed/table", last_dim),
        ("unembed/kernel", last_dim),
        ("meta_tokens", replicated),
        ("pos_embed", replicated),
        # attention
        ("wq/kernel", headsharded),
        ("wk/kernel", headsharded),
        ("wv/kernel", headsharded),
        ("wq/bias", lambda s: P(model_axis, None) if s[0] % msize == 0
         else P(*[None] * len(s))),
        ("wk/bias", lambda s: P(model_axis, None) if s[0] % msize == 0
         else P(*[None] * len(s))),
        ("wv/bias", lambda s: P(model_axis, None) if s[0] % msize == 0
         else P(*[None] * len(s))),
        ("wo/kernel", first_dim),
        # MLA
        ("wq_a/kernel", replicated),
        ("wq_b/kernel", headsharded),
        ("wkv_a/kernel", replicated),
        ("wkv_b/kernel", headsharded),
        # MoE experts: (E, d, f) / (E, f, d) — expert parallel on E.
        ("moe/gate", first_dim),
        ("moe/up", first_dim),
        ("moe/down", first_dim),
        ("router/kernel", replicated),
        # dense MLPs (also MoE shared expert / vlm x_mlp)
        ("gate/kernel", last_dim),
        ("up/kernel", last_dim),
        ("down/kernel", first_dim),
        # SSM
        ("in_proj/kernel", last_dim),
        ("x_proj/kernel", first_dim),
        ("dt_proj/kernel", last_dim),
        ("dt_bias", lambda s: P(model_axis) if s[0] % msize == 0 else P(None)),
        ("a_log", first_dim),
        ("d_skip", lambda s: P(model_axis) if s[0] % msize == 0 else P(None)),
        ("conv_bias", lambda s: P(model_axis) if s[0] % msize == 0 else P(None)),
        ("conv", last_dim),
        ("out_proj/kernel", first_dim),
        # RWKV
        ("tmix/wr/kernel", last_dim), ("tmix/wk/kernel", last_dim),
        ("tmix/wv/kernel", last_dim), ("tmix/wg/kernel", last_dim),
        ("tmix/wo/kernel", first_dim),
        ("cmix/wk/kernel", last_dim), ("cmix/wv/kernel", first_dim),
        ("cmix/wr/kernel", replicated),
        ("u", first_dim),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def partition_spec(cfg: ModelConfig, params_shape: PyTree,
                   model_axis: str = "model", model_axis_size: int = 1,
                   fsdp_axis: Optional[str] = None, fsdp_size: int = 1,
                   fsdp_min_size: int = 2**16,
                   ctr_heads: bool = False) -> PyTree:
    """PartitionSpec pytree matching ``params_shape`` (from eval_shape).

    Stacked layer axes are detected by path ("blocks"/"selfs") and get a
    leading None. With ``fsdp_axis``, the largest still-unsharded dim of
    big tensors is additionally sharded over it (ZeRO-ish weight
    sharding, a §Perf memory lever).
    """
    rules = _spec_rules(model_axis, model_axis_size, ctr_heads)

    def assign(path, leaf):
        ps = _path_str(path)
        comps = ps.split("/")
        stacked = sum(("blocks" in comps, "selfs" in comps))
        shape = leaf.shape[stacked:]
        spec = None
        for frag, fn in rules:
            fc = frag.split("/")
            if any(comps[i:i + len(fc)] == fc
                   for i in range(len(comps) - len(fc) + 1)):
                spec = fn(shape)
                break
        if spec is None:
            spec = P(*([None] * len(shape)))
        parts = list(spec)
        if fsdp_axis and leaf.size >= fsdp_min_size:
            # shard the largest unsharded dim over the fsdp axis.
            cand = [(shape[i], i) for i in range(len(shape))
                    if parts[i] is None and shape[i] % fsdp_size == 0]
            if cand:
                _, i = max(cand)
                parts[i] = fsdp_axis
        return P(*([None] * stacked), *parts)

    return jax.tree_util.tree_map_with_path(assign, params_shape)
