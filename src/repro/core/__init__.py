"""Core ADOTA-FL library: OTA channel, adaptive server optimizers, FL loop."""

from repro.core.adaptive import (AdaptiveConfig, ServerOptimizer, ServerOptState,
                                 adagrad_ota, adam_ota, amsgrad_ota, fedavg,
                                 fedavgm, make_server_optimizer, yogi_ota)
from repro.core.channel import (OTAChannelConfig, sample_alpha_stable,
                                sample_fading, sample_interference, upsilon)
from repro.core.fl import (FLConfig, RoundMetrics, init_server, make_round_step,
                           make_sharded_round_step, run_rounds)
from repro.core.ota import (add_interference, faded_loss_weights,
                            ota_aggregate_stacked, ota_psum)
from repro.core.tail_index import hill_estimate, log_moment_estimate

__all__ = [
    "AdaptiveConfig", "ServerOptimizer", "ServerOptState", "adagrad_ota",
    "adam_ota", "fedavg", "fedavgm", "make_server_optimizer", "yogi_ota",
    "amsgrad_ota", "OTAChannelConfig", "sample_alpha_stable", "sample_fading",
    "sample_interference", "upsilon", "FLConfig", "RoundMetrics",
    "init_server", "make_round_step", "make_sharded_round_step", "run_rounds",
    "add_interference", "faded_loss_weights", "ota_aggregate_stacked",
    "ota_psum", "hill_estimate", "log_moment_estimate",
]
