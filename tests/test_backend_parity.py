"""jnp vs pallas (slab engine) backend parity.

The slab engine must be a drop-in replacement: every server optimizer,
the OTA MAC, and the full round must produce the same params/opt-state
as the per-leaf tree.map reference — to f32 rounding for f32 params
(both backends consume identical PRNG draws), and to bf16 resolution
when the aggregation itself runs at bf16 on the jnp path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step, make_server_optimizer,
                        ota_aggregate_stacked)

OPTIMIZERS = ["adagrad_ota", "adam_ota", "amsgrad_ota", "yogi_ota",
              "fedavgm", "fedavg"]

# Non-lane-multiple leaf sizes on purpose (LANE == 128).
SHAPES = [(3, 45), (130,), (1,), (257,)]


def _params(key, dtype):
    ks = jax.random.split(key, len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s, dtype)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _grads_like(key, params):
    ks = jax.random.split(key, len(jax.tree.leaves(params)))
    return jax.tree.unflatten(
        jax.tree.structure(params),
        [jax.random.normal(k, p.shape, p.dtype)
         for k, p in zip(ks, jax.tree.leaves(params))])


def _assert_trees_close(a, b, rtol, atol):
    assert jax.tree.structure(a) == jax.tree.structure(b)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("name", OPTIMIZERS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_optimizer_update_parity(name, dtype):
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    params = _params(jax.random.key(1), dtype)
    cfg = AdaptiveConfig(optimizer=name, lr=0.05, alpha=1.5, beta2=0.3)
    ref_opt = make_server_optimizer(cfg)
    slab_opt = make_server_optimizer(
        dataclasses.replace(cfg, backend="pallas"))
    p_r, p_s = params, params
    s_r, s_s = ref_opt.init(params), slab_opt.init(params)
    for t in range(3):   # a few steps so second-moment state accumulates
        g = _grads_like(jax.random.key(10 + t), params)
        p_r, s_r = ref_opt.update(g, s_r, p_r)
        p_s, s_s = slab_opt.update(g, s_s, p_s)
    _assert_trees_close(p_r, p_s, tol, tol)
    _assert_trees_close(s_r.delta, s_s.delta, tol, tol)
    _assert_trees_close(s_r.nu, s_s.nu, tol, tol)
    assert int(s_s.step) == 3


@pytest.mark.parametrize("interference", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_aggregate_parity(interference, dtype):
    # bf16: the jnp path reduces over clients at bf16, the slab at f32.
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    n = 9
    grads = {f"p{i}": jax.random.normal(jax.random.key(40 + i), (n,) + s,
                                        dtype)
             for i, s in enumerate(SHAPES)}
    cfg = OTAChannelConfig(alpha=1.5, xi_scale=0.2, interference=interference)
    key = jax.random.key(7)
    g_ref, h_ref = ota_aggregate_stacked(key, cfg, grads)
    g_slab, h_slab = ota_aggregate_stacked(
        key, dataclasses.replace(cfg, backend="pallas"), grads)
    np.testing.assert_array_equal(np.asarray(h_ref), np.asarray(h_slab))
    _assert_trees_close(g_ref, g_slab, tol, tol)
    for leaf in jax.tree.leaves(g_slab):
        assert leaf.dtype == dtype


@pytest.mark.parametrize("name", OPTIMIZERS)
def test_full_round_parity(name):
    """Acceptance: make_round_step(backend="pallas") matches the jnp
    backend within 1e-5 rtol for every registered optimizer (f32,
    interference ON)."""
    params = _params(jax.random.key(2), jnp.float32)

    def loss_fn(p, batch):
        return sum(jnp.mean((x.astype(jnp.float32) - b) ** 2)
                   for x, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(batch)))

    n = 6
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (n,) + p.shape), params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer=name, lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)

    outs = {}
    for backend in ("jnp", "pallas"):
        rs = make_round_step(loss_fn, ch, ad, fl, backend=backend)
        state = init_server(params, ad)
        p, s, m = params, state, None
        for t in range(2):
            p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(9), t),
                         batches)
        outs[backend] = (p, s, m)
    p_r, s_r, m_r = outs["jnp"]
    p_s, s_s, m_s = outs["pallas"]
    _assert_trees_close(p_r, p_s, 1e-5, 1e-5)
    _assert_trees_close(s_r.delta, s_s.delta, 1e-5, 1e-5)
    _assert_trees_close(s_r.nu, s_s.nu, 1e-5, 1e-5)
    np.testing.assert_allclose(float(m_r.loss), float(m_s.loss), rtol=1e-6)
    np.testing.assert_allclose(float(m_r.noisy_grad_norm),
                               float(m_s.noisy_grad_norm), rtol=1e-4)


def test_round_executes_exactly_two_kernel_launches(monkeypatch):
    """Acceptance: one ota_channel_slab + one adaptive_update_slab call
    over the FULL model per round — not one per leaf."""
    from repro.core import ota as core_ota
    from repro.kernels import adaptive_update as au_mod
    from repro.kernels import ota_channel as oc_mod

    calls = {"ota": 0, "update": 0}
    real_ota, real_upd = oc_mod.ota_channel_slab, au_mod.adaptive_update_slab

    def count_ota(*a, **k):
        calls["ota"] += 1
        return real_ota(*a, **k)

    def count_upd(*a, **k):
        calls["update"] += 1
        return real_upd(*a, **k)

    # Patch where the core modules resolve the kernels: core.ota binds
    # ota_channel_slab at import time, adaptive still imports lazily.
    monkeypatch.setattr(core_ota, "ota_channel_slab", count_ota)
    monkeypatch.setattr(au_mod, "adaptive_update_slab", count_upd)

    params = _params(jax.random.key(5), jnp.float32)

    def loss_fn(p, batch):
        return sum(jnp.mean((x - b) ** 2)
                   for x, b in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(batch)))

    n = 4
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(6), (n,) + p.shape), params)
    ch = OTAChannelConfig()
    ad = AdaptiveConfig(optimizer="adam_ota")
    rs = make_round_step(loss_fn, ch, ad, FLConfig(n_clients=n), jit=False,
                         backend="pallas")
    state = init_server(params, ad)
    rs(params, state, jax.random.key(0), batches)
    assert calls == {"ota": 1, "update": 1}, calls


def test_backend_resolution_and_validation():
    from repro.core.fl import _resolve_backend
    # either config requesting pallas switches the whole round
    backend, ch2, ad2 = _resolve_backend(None, OTAChannelConfig(backend="pallas"),
                                         AdaptiveConfig())
    assert backend == "pallas"
    assert ch2.backend == ad2.backend == "pallas"
    with pytest.raises(ValueError):
        AdaptiveConfig(backend="tpu")
    with pytest.raises(ValueError):
        OTAChannelConfig(backend="cuda")
