"""Local (client-side) optimizers + LR schedules.

The paper's CLIENTUPDATE returns a plain gradient, but the framework also
supports multi-step local training (FedAvg-style); these are the
optimizers clients use locally, plus schedules for the server's eta.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class LocalOpt(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]
    name: str


def sgd(lr: float) -> LocalOpt:
    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        return new, state

    return LocalOpt(init, update, "sgd")


def momentum(lr: float, beta: float = 0.9) -> LocalOpt:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        m = jax.tree.map(lambda s, g: beta * s + g.astype(jnp.float32),
                         state, grads)
        new = jax.tree.map(lambda p, mi: (p - lr * mi).astype(p.dtype),
                           params, m)
        return new, m

    return LocalOpt(init, update, "momentum")


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> LocalOpt:
    class State(NamedTuple):
        step: jax.Array
        m: PyTree
        v: PyTree

    def init(params):
        z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return State(jnp.zeros((), jnp.int32), z(), z())

    def update(grads, state, params):
        t = state.step + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return (p - step - lr * weight_decay * p.astype(jnp.float32)
                    ).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), State(t, m, v)

    return LocalOpt(init, update, "adamw")


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr_at(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(math.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr_at


def constant_schedule(base_lr: float) -> Callable[[jax.Array], jax.Array]:
    return lambda step: jnp.asarray(base_lr, jnp.float32)
