"""The int8-quantized over-the-air MAC: adam_ota convergence is
preserved when the uplink carries int8 payloads + per-128-block f32
scales instead of raw f32 — at ~4x fewer wire bytes per round.

Runs the same ADOTA task twice (identical round keys, so both
trajectories see the same fading and interference draws) and prints the
loss/accuracy side by side with the per-round MAC payload sizes.

    PYTHONPATH=src python examples/quantized_uplink.py
"""

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_server, make_round_step,
                        make_slab_spec, run_rounds)
from repro.data import FederatedBatcher, gaussian_mixture
from repro.models.vision import accuracy, logistic_regression

N_CLIENTS = 20
ROUNDS = 60


def train(uplink: str):
    data = gaussian_mixture(4000, 32, 10, seed=0)
    model = logistic_regression(32, 10)
    batcher = FederatedBatcher(data, N_CLIENTS, 16, dir_alpha=0.1)

    channel = OTAChannelConfig(alpha=1.5, xi_scale=0.5,   # strong interference
                               uplink=UplinkConfig(mode=uplink))
    server = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5,
                            beta2=0.3)
    round_step = make_round_step(model.loss_fn, channel, server,
                                 FLConfig(n_clients=N_CLIENTS))
    params = model.init(jax.random.key(0))
    state = init_server(params, server)

    def batch_fn(t, key):
        b = batcher(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    params, state, hist = run_rounds(round_step, params, state,
                                     jax.random.key(1), batch_fn,
                                     n_rounds=ROUNDS, log_every=20)
    acc = accuracy(model, params, jnp.asarray(data.x), data.y)
    spec = make_slab_spec(params)
    wire = (spec.padded * 1 + (spec.padded // 128) * 4 if uplink == "int8"
            else spec.padded * 4)
    print(f"uplink={uplink:5s} final loss {hist[-1]['loss']:.4f}  "
          f"acc {acc:.4f}  MAC payload {wire} B/round")
    return hist[-1]["loss"], acc


if __name__ == "__main__":
    print("== analog f32 uplink (paper Eq. 7) ==")
    loss_f32, acc_f32 = train("f32")
    print("== int8 uplink (quantize-on-write MAC) ==")
    loss_i8, acc_i8 = train("int8")
    print(f"\naccuracy delta under the quantized MAC: "
          f"{(acc_i8 - acc_f32) * 100:+.2f} pts "
          "(rounding noise is tiny next to the channel noise)")
