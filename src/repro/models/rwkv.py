"""RWKV-6 "Finch" — attention-free token mixing with data-dependent decay.

Per head (key dim D = value dim D):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t            (state, D x D)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with per-channel, per-token decay w_t = exp(-exp(wlog_t)) produced from
the input (the "data-dependent decay" that distinguishes Finch/RWKV-6
from RWKV-5), and token-shift ddlerp input mixing.

Sequence processing uses the *chunked* form (production linear-attention
scheme): within a chunk of length L the contributions are an L x L
matmul with decay ratios; across chunks only the D x D state is carried
by ``lax.scan``. Decode carries S directly — O(1) per token, no KV
cache — so rwkv6 takes the long_500k shape natively.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init, layernorm, layernorm_init


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    n_heads: int               # head_dim = d_model // n_heads
    d_ff: int
    lora_rank: int = 64        # decay/mix LoRA rank
    chunk: int = 64            # chunked-scan block length

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def time_mix_init(key, cfg: RWKVConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 10)
    d, r = cfg.d_model, cfg.lora_rank
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        # ddlerp token-shift mixing: 5 targets (r, k, v, w, g).
        "mix_base": jnp.zeros((5, d), jnp.float32),
        "mix_w1": dense_init(ks[0], d, (5 * r,), dtype),
        "mix_w2": (jax.random.normal(ks[1], (5, r, d), jnp.float32)
                   * 0.01).astype(dtype),
        "wr": dense_init(ks[2], d, (d,), dtype),
        "wk": dense_init(ks[3], d, (d,), dtype),
        "wv": dense_init(ks[4], d, (d,), dtype),
        "wg": dense_init(ks[5], d, (d,), dtype),
        "wo": dense_init(ks[6], d, (d,), dtype),
        # data-dependent decay: w = exp(-exp(w0 + lora(x_w)))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "w_lora_a": dense_init(ks[7], d, (r,), dtype),
        "w_lora_b": (jax.random.normal(ks[8], (r, d), jnp.float32)
                     * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (h, hd), jnp.float32) * 0.1),
        "ln_x": layernorm_init(d),   # per-head group-norm approximated by LN
    }


def channel_mix_init(key, cfg: RWKVConfig, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mix_k": jnp.zeros((cfg.d_model,), jnp.float32),
        "mix_r": jnp.zeros((cfg.d_model,), jnp.float32),
        "wk": dense_init(k1, cfg.d_model, (cfg.d_ff,), dtype),
        "wr": dense_init(k2, cfg.d_model, (cfg.d_model,), dtype),
        "wv": dense_init(k3, cfg.d_ff, (cfg.d_model,), dtype),
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """Return x_{t-1} (with supplied state for t == 0). x: (B,S,d)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: jax.Array, xx: jax.Array) -> jax.Array:
    """Data-dependent lerp producing the 5 mixed inputs (5, B, S, d)."""
    base = x[None] + xx[None] * p["mix_base"][:, None, None]
    lo = jnp.tanh(dense(p["mix_w1"], x + xx * 0.5))
    lo = jnp.moveaxis(lo.reshape(*lo.shape[:-1], 5, -1), -2, 0)  # (5,B,S,r)
    delta = jnp.einsum("fbsr,frd->fbsd", lo.astype(jnp.float32),
                       p["mix_w2"].astype(jnp.float32))
    return base + xx[None] * delta.astype(x.dtype)


def _rkvwg(p: dict, cfg: RWKVConfig, x: jax.Array, x_prev: jax.Array):
    xx = _token_shift(x, x_prev) - x
    m = _ddlerp(p, x, xx)
    r = dense(p["wr"], m[0])
    k = dense(p["wk"], m[1])
    v = dense(p["wv"], m[2])
    lora = jnp.tanh(dense(p["w_lora_a"], m[3]))
    wlog = (p["w0"][None, None]
            + jnp.einsum("bsr,rd->bsd", lora.astype(jnp.float32),
                         p["w_lora_b"].astype(jnp.float32)))
    w = jnp.exp(-jnp.exp(jnp.clip(wlog, -10.0, 2.0)))    # decay in (0,1)
    g = jax.nn.silu(dense(p["wg"], m[4]).astype(jnp.float32)).astype(x.dtype)
    hshape = x.shape[:-1] + (cfg.n_heads, cfg.head_dim)
    return (r.reshape(hshape), k.reshape(hshape), v.reshape(hshape),
            w.reshape(hshape), g)


def _wkv_chunked(r, k, v, w, u, chunk: int):
    """Chunked WKV recurrence. r,k,v,w: (B,S,H,D) (w in f32, decay in (0,1));
    u: (H,D). Returns y (B,S,H,D) f32."""
    b, s, h, d = r.shape
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        w = jnp.pad(w, padw, constant_values=1.0)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    wf = w.astype(jnp.float32)
    # (n, B, H, L, D) chunked layout.
    def chunked(t):
        return t.reshape(b, n, chunk, h, d).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, wc = map(chunked, (rf, kf, vf, wf))
    a_ex = jnp.cumprod(wc, axis=-2) / wc          # exclusive cumprod A_t
    a_in = jnp.cumprod(wc, axis=-2)               # inclusive cumprod
    tot = a_in[..., -1:, :]                       # whole-chunk decay

    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def step(s_state, xs):
        rj, kj, vj, aex, ain, totj, wj = xs
        r_dec = rj * aex                                  # (B,H,L,D)
        k_inc = kj / jnp.maximum(ain, 1e-30)
        scores = jnp.einsum("bhld,bhmd->bhlm", r_dec, k_inc)
        scores = jnp.where(mask[None, None], scores, 0.0)
        diag = jnp.einsum("bhld,bhld->bhl", rj * u[None, :, None, :], kj)
        y = (jnp.einsum("bhlm,bhmd->bhld", scores, vj)
             + diag[..., None] * vj
             + jnp.einsum("bhld,bhde->bhle", r_dec, s_state))
        carry_k = kj * (totj / jnp.maximum(ain, 1e-30))   # decay to chunk end
        # S_new[d, e] = tot[d] * S[d, e] + sum_l carry_k[l, d] v[l, e]
        s_new = (s_state * totj[..., 0, :][..., :, None]
                 + jnp.einsum("bhld,bhle->bhde", carry_k, vj))
        return s_new, y

    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    s_fin, ys = jax.lax.scan(step, s0, (rc, kc, vc, a_ex, a_in, tot, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, n * chunk, h, d)
    return y[:, :s], s_fin


def time_mix_forward(p: dict, cfg: RWKVConfig, x: jax.Array,
                     x_prev=None, return_state: bool = False):
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, 0])
    r, k, v, w, g = _rkvwg(p, cfg, x, x_prev)
    y, s_fin = _wkv_chunked(r, k, v, w, p["u"], cfg.chunk)
    y = y.reshape(*x.shape).astype(x.dtype)
    y = layernorm(p["ln_x"], y)
    out = dense(p["wo"], y * g)
    if return_state:
        return out, s_fin, x[:, -1]
    return out


def channel_mix_forward(p: dict, cfg: RWKVConfig, x: jax.Array,
                        x_prev=None) -> jax.Array:
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, 0])
    xx = _token_shift(x, x_prev) - x
    xk = x + xx * p["mix_k"].astype(x.dtype)
    xr = x + xx * p["mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(dense(p["wk"], xk).astype(jnp.float32)))
    rr = jax.nn.sigmoid(dense(p["wr"], xr).astype(jnp.float32))
    return (rr * dense(p["wv"], kk.astype(x.dtype)).astype(jnp.float32)
            ).astype(x.dtype)


# --------------------------------------------------------------------------
# O(1) decode.
# --------------------------------------------------------------------------

def init_rwkv_cache(batch: int, cfg: RWKVConfig, dtype=jnp.bfloat16) -> dict:
    return {
        "state": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.head_dim),
                           jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), dtype),   # time-mix shift
        "x_cm": jnp.zeros((batch, cfg.d_model), dtype),   # channel-mix shift
    }


def time_mix_decode(p: dict, cfg: RWKVConfig, x: jax.Array, cache: dict
                    ) -> Tuple[jax.Array, dict]:
    """x: (B, 1, d). S_t update + output in O(D^2) per head."""
    r, k, v, w, g = _rkvwg(p, cfg, x, cache["x_tm"])
    rf, kf, vf = (t.astype(jnp.float32)[:, 0] for t in (r, k, v))  # (B,H,D)
    wf = w.astype(jnp.float32)[:, 0]
    s = cache["state"]
    kv = jnp.einsum("bhd,bhe->bhde", kf, vf)
    y = jnp.einsum("bhd,bhde->bhe", rf, s + p["u"][None, ..., None] * kv)
    s_new = s * wf[..., None] + kv
    y = y.reshape(x.shape[0], 1, -1).astype(x.dtype)
    y = layernorm(p["ln_x"], y)
    out = dense(p["wo"], y * g)
    return out, {**cache, "state": s_new, "x_tm": x[:, 0]}


def channel_mix_decode(p: dict, cfg: RWKVConfig, x: jax.Array, cache: dict
                       ) -> Tuple[jax.Array, dict]:
    prev = cache["x_cm"]
    xx = prev[:, None] - x
    xk = x + xx * p["mix_k"].astype(x.dtype)
    xr = x + xx * p["mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(dense(p["wk"], xk).astype(jnp.float32)))
    rr = jax.nn.sigmoid(dense(p["wr"], xr).astype(jnp.float32))
    out = (rr * dense(p["wv"], kk.astype(x.dtype)).astype(jnp.float32)
           ).astype(x.dtype)
    return out, {**cache, "x_cm": x[:, 0]}
