"""Federated LM training driver (real execution).

Runs ADOTA-FL on an assigned architecture's REDUCED variant (CPU) or the
full config (TPU pod, same code path): clients hold Dirichlet-partitioned
shards of a synthetic token stream, each round computes client gradients,
passes them through the simulated OTA MAC, and applies the adaptive
server update. Checkpoints every --ckpt-every rounds.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --preset tiny --rounds 100
    PYTHONPATH=src python -m repro.launch.train --preset 100m --rounds 300
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.checkpoint as ckpt
from repro.configs import ARCHS, get_config, smoke_config
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step, run_rounds)
from repro.data import dirichlet_partition, token_stream
from repro.models.model import ModelConfig, build_model


def preset_config(arch: str, preset: str) -> ModelConfig:
    if preset == "full":
        return get_config(arch)
    if preset == "tiny":
        return dataclasses.replace(smoke_config(arch), vocab=257)
    if preset == "100m":
        # ~100M-parameter decoder (qwen-style), the end-to-end driver size.
        return ModelConfig(
            arch=f"{arch}-100m", family="dense", n_layers=8, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=8192, qk_norm=True,
            remat=False)
    raise ValueError(preset)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-14b")
    ap.add_argument("--preset", choices=["tiny", "100m", "full"],
                    default="tiny")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="adam_ota",
                    choices=["adam_ota", "adagrad_ota", "amsgrad_ota",
                             "yogi_ota", "fedavgm", "fedavg"])
    ap.add_argument("--backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_sharded"],
                    help="round-step backend: per-leaf jnp tree.map, the "
                         "fused Pallas slab engine (2 kernel launches/"
                         "round), or the mesh-distributed slab engine "
                         "(2 launches per DEVICE + cross-client psum)")
    ap.add_argument("--mesh", default=None,
                    help="client-mesh shape for --backend pallas_sharded, "
                         "comma-separated (e.g. '2' or '4,2', default 2); "
                         "the client count must be divisible by its product")
    ap.add_argument("--no-interpret", action="store_true",
                    help="compile the Pallas kernels (real TPU) instead of "
                         "interpret mode")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=1.5)
    ap.add_argument("--xi-scale", type=float, default=0.05)
    ap.add_argument("--dir", type=float, default=0.5,
                    help="Dirichlet concentration (data heterogeneity)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    mesh = None
    if args.mesh is not None and args.backend != "pallas_sharded":
        ap.error(f"--mesh only applies to --backend pallas_sharded "
                 f"(got --backend {args.backend}); it would be silently "
                 f"ignored on a single-device backend")
    if args.backend == "pallas_sharded":
        import math

        from repro.launch.hostdev import force_host_devices
        try:
            mesh_shape = tuple(int(x) for x in (args.mesh or "2").split(","))
            if not mesh_shape or any(s < 1 for s in mesh_shape):
                raise ValueError
        except ValueError:
            ap.error(f"--mesh must be comma-separated positive ints "
                     f"(e.g. '2' or '4,2'), got {args.mesh!r}")
        # A CPU host exposes one device; force enough host devices for
        # the mesh BEFORE jax initialises its backend (first jax array
        # op locks the count).
        force_host_devices(math.prod(mesh_shape))

    cfg = preset_config(args.arch, args.preset)
    model = build_model(cfg)
    print(f"arch={cfg.arch} params={cfg.n_params()/1e6:.1f}M "
          f"vocab={cfg.vocab} clients={args.clients}")

    # Client corpora: one shared stream, Dirichlet-partitioned by "domain"
    # id so clients see different mixtures (non-iid).
    toks = token_stream(2_000_000, vocab=cfg.vocab, seed=args.seed)
    n_windows = (len(toks) - args.seq - 1) // args.seq
    starts_all = np.arange(n_windows) * args.seq
    domain = (starts_all // (len(toks) // 16)).astype(np.int64)  # 16 domains
    parts = dirichlet_partition(domain, args.clients, args.dir,
                                seed=args.seed, min_per_client=args.batch)
    rng = np.random.default_rng(args.seed)

    def batch_fn(t, key):
        out = np.empty((args.clients, args.batch, args.seq), np.int32)
        for c, p in enumerate(parts):
            pick = rng.choice(p, size=args.batch, replace=len(p) < args.batch)
            for j, w in enumerate(pick):
                s = starts_all[w]
                out[c, j] = toks[s:s + args.seq]
        return {"tokens": jnp.asarray(out)}

    interpret = not args.no_interpret
    ch = OTAChannelConfig(alpha=args.alpha, xi_scale=args.xi_scale,
                          backend=args.backend, interpret=interpret)
    ad = AdaptiveConfig(optimizer=args.optimizer, lr=args.lr,
                        alpha=args.alpha, beta2=0.3, backend=args.backend,
                        interpret=interpret)
    if args.backend == "pallas_sharded":
        from repro.launch.mesh import make_client_mesh
        mesh = make_client_mesh(mesh_shape)
        print(f"client mesh {dict(mesh.shape)} "
              f"({len(jax.devices())} devices visible)")
    rs = make_round_step(lambda p, b: model.loss_fn(p, b), ch, ad,
                         FLConfig(n_clients=args.clients), mesh=mesh)
    params = model.init(jax.random.key(args.seed))
    state = init_server(params, ad)

    start_round = 0
    if args.ckpt_dir:
        latest = ckpt.latest_round(args.ckpt_dir)
        if latest:
            tree = ckpt.load(latest, {"params": params, "state": state,
                                      "round": jnp.asarray(0)})
            params, state = tree["params"], tree["state"]
            start_round = int(tree["round"])
            print(f"resumed from {latest} at round {start_round}")

    t0 = time.time()
    history = []
    for t in range(start_round, args.rounds):
        key = jax.random.fold_in(jax.random.key(args.seed + 1), t)
        params, state, m = rs(params, state, key, batch_fn(t, None))
        rec = {"round": t, "loss": float(m.loss),
               "grad_norm": float(m.grad_norm)}
        history.append(rec)
        if (t + 1) % args.log_every == 0:
            dt = time.time() - t0
            print(f"round {t+1:5d}  loss {rec['loss']:.4f}  "
                  f"|g| {rec['grad_norm']:.3e}  ({dt/ (t - start_round + 1):.2f}s/round)",
                  flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            ckpt.save(os.path.join(args.ckpt_dir, f"round_{t+1}.npz"),
                      {"params": params, "state": state,
                       "round": jnp.asarray(t + 1)})
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f)
    if history:
        print(f"done: final loss {history[-1]['loss']:.4f} "
              f"(started {history[0]['loss']:.4f})")
    else:
        print(f"done: nothing to do (resumed at round {start_round} "
              f">= --rounds {args.rounds})")


if __name__ == "__main__":
    main()
