"""Input ShapeDtypeStructs + PartitionSpecs for every (arch x shape).

The four assigned input shapes (see DESIGN.md §5):

    train_4k     seq 4096,   global batch 256   -> train_step
    prefill_32k  seq 32768,  global batch 32    -> prefill
    decode_32k   seq 32768,  global batch 128   -> decode_step (1 token)
    long_500k    seq 524288, global batch 1     -> decode_step, sub-quadratic

No arrays are allocated here — everything is ShapeDtypeStruct, matching
the dry-run contract.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import ModelConfig

PyTree = Any

INPUT_SHAPES: Dict[str, Dict] = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}

LONG_CONTEXT_WINDOW = 4096   # beyond-paper sliding window for dense archs


def shape_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Arch config adjusted for an input shape.

    long_500k requires sub-quadratic attention: SSM/RWKV archs are
    natively O(1)-state; dense/MoE/encdec archs get the sliding-window
    variant (window=4096) if they don't already have a native window.
    """
    if shape_name == "long_500k" and cfg.family not in ("rwkv",):
        if cfg.window is None:
            cfg = dataclasses.replace(cfg, window=LONG_CONTEXT_WINDOW,
                                      notes=cfg.notes + " +window4k(long)")
    return cfg


def _dp(mesh, batch: int) -> Optional[Tuple[str, ...]]:
    """Data axes usable for this batch size (None if not divisible)."""
    axes = tuple(a for a in mesh.axis_names if a != "model")
    total = math.prod(mesh.shape[a] for a in axes)
    return axes if batch % total == 0 else None


def cache_length(cfg: ModelConfig, seq: int) -> int:
    return min(seq, cfg.window) if cfg.window else seq


def batch_struct(cfg: ModelConfig, shape_name: str, mesh
                 ) -> Tuple[PyTree, PyTree]:
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the data batch."""
    sh = INPUT_SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    dp = _dp(mesh, b)
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    specs = {"tokens": P(dp, None)}
    if cfg.family == "encdec":
        batch["audio_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.float32)
        specs["audio_embed"] = P(dp, None, None)
    if cfg.family == "vlm":
        batch["image_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
        specs["image_embed"] = P(dp, None, None)
    return batch, specs


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def cache_partition_spec(cache_shapes: PyTree, mesh, batch: int,
                         model_divides, shard_cache_seq: bool = False
                         ) -> PyTree:
    """Assign PartitionSpecs to decode-cache leaves by name + trailing
    dims. Leading stacked layer/group axes are replicated.

    shard_cache_seq: additionally shard the KV-cache sequence dim over
    "model" (flash-decoding-style split-KV — a §Perf lever for the
    decode shapes; GSPMD inserts the partial-softmax collectives).
    """
    dp = _dp(mesh, batch)
    m = "model"

    def assign(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        sp = [None] * nd

        def set_at(i, ax, dim):
            if ax and dim % (math.prod(mesh.shape[a] for a in
                                       ((ax,) if isinstance(ax, str) else ax))
                             ) == 0:
                sp[i] = ax

        if name in ("k", "v"):                     # (..., B, S, K, D)
            set_at(nd - 4, dp, leaf.shape[nd - 4])
            if shard_cache_seq and model_divides(leaf.shape[nd - 3]):
                sp[nd - 3] = m
        elif name in ("c_kv", "k_pe"):             # (..., B, S, r)
            set_at(nd - 3, dp, leaf.shape[nd - 3])
            if shard_cache_seq and model_divides(leaf.shape[nd - 2]):
                sp[nd - 2] = m
        elif name == "state":                      # (..., B, H, D, D)
            set_at(nd - 4, dp, leaf.shape[nd - 4])
            if model_divides(leaf.shape[nd - 3]):
                sp[nd - 3] = m
        elif name in ("x_tm", "x_cm"):             # (..., B, d)
            set_at(nd - 2, dp, leaf.shape[nd - 2])
            if model_divides(leaf.shape[nd - 1]):
                sp[nd - 1] = m
        elif name == "h":                          # (..., B, C, N)
            set_at(nd - 3, dp, leaf.shape[nd - 3])
            if model_divides(leaf.shape[nd - 2]):
                sp[nd - 2] = m
        elif name == "conv":                       # (..., B, K, C)
            set_at(nd - 3, dp, leaf.shape[nd - 3])
            if model_divides(leaf.shape[nd - 1]):
                sp[nd - 1] = m
        elif name in ("enc_out", "image_embed"):   # (B, S, d)
            set_at(0, dp, leaf.shape[0])
        # "pos" and anything else: replicated.
        return P(*sp)

    return jax.tree_util.tree_map_with_path(assign, cache_shapes)


def named(mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
