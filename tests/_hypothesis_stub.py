"""Minimal deterministic stand-in for ``hypothesis`` (see conftest.py).

The container may not ship hypothesis; without it every test module
errored at import. This stub implements just the surface the suite uses
(``given``/``settings``/``strategies.{integers,floats,sampled_from}``)
so the suite collects and RUNS everywhere: each ``@given`` test executes
``_EXAMPLES`` deterministic draws (seeded per test name, so failures
reproduce). Install the real package (requirements-dev.txt) to get full
shrinking/coverage; the stub is a fallback, not a replacement.
"""

from __future__ import annotations

import random
import sys
import types

_EXAMPLES = 5


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rnd: rnd.choice(elements))


def booleans() -> _Strategy:
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def given(**strategies):
    def decorate(fn):
        # No functools.wraps: copying __wrapped__ would make pytest read
        # the original signature and demand fixtures for the drawn args.
        def run(*args, **kwargs):
            rnd = random.Random(fn.__name__)
            for _ in range(_EXAMPLES):
                drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        return run
    return decorate


def settings(**_kwargs):
    def decorate(fn):
        return fn
    return decorate


def install() -> None:
    """Register this stub as ``hypothesis`` (+``hypothesis.strategies``)."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
