"""Kimi-K2 1T-A32B [arXiv:2501.kimi2, paper table]: 61L, d_model 7168,
64 heads (GQA kv=8, head_dim 112), MoE 384 experts top-8 with expert
d_ff 2048 + 1 shared expert, vocab 163840. ~1.04T params, ~32B active.
NOTE: full training state does not fit one 256-chip v5e pod; reported
honestly in EXPERIMENTS.md (the multi-pod run is the realistic one)."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840, n_experts=384, top_k=8, n_shared_experts=1,
    notes="Kimi K2 trillion-param MoE [arXiv:2501.kimi2]",
)
