"""Federated LM training driver (real execution).

Runs ADOTA-FL on an assigned architecture's REDUCED variant (CPU) or the
full config (TPU pod, same code path): clients hold Dirichlet-partitioned
shards of a synthetic token stream, each round computes client gradients,
passes them through the simulated OTA MAC, and applies the adaptive
server update.

The training state lives as a slab-resident ``SlabTrainState`` across
rounds (PR 3): params + optimizer-state slabs, sharded over the mesh
under ``--backend pallas_sharded``, with rounds dispatched as
``jax.lax.scan`` chunks of ``--scan-rounds``. Checkpoints
(``--ckpt-dir``, every ``--ckpt-every`` rounds) store the slabs raw
with a layout fingerprint; ``--resume`` continues bitwise-identically
from the latest one (all round randomness is keyed by absolute round
index, so the resumed trajectory equals the uninterrupted one).
``--uplink int8`` switches the MAC payload to the quantized uplink
(int8 codewords + per-128-block f32 scales, ~4x fewer collective bytes
per round on the sharded mesh); ``--uplink sign`` to the 1-bit signSGD
uplink (~32x, deterministic); the default f32 uplink is bitwise-
identical to the pre-pipeline code. ``--error-feedback`` carries each
transmitter's quantization residual across rounds (resident in the
slab state, checkpointed) so the quantized uplinks recover the f32
convergence trajectory; ``--downlink int8`` quantizes the per-round
model broadcast the clients see (the server keeps f32 master weights).

The compiled-mode fast path (PR 8) is on by default where it applies:
the slab state is DONATED into each scan chunk (in-place resident
update, no 2x state copy — ``--no-donate`` to disable,
``--donation-report`` to verify the executable aliases the buffers),
``--uplink sign`` rides a uint32 bit-packed wire (``--sign-pack``:
'fold' 1 bit/coord, 'planes' 2, 'int8' the PR 7 container), and
``--sr-inkernel`` moves the int8 stochastic-rounding draws into the
transmit kernel's pltpu PRNG (compiled mode only; same quantization
contract, different uniform stream).

``--client-chunk`` streams the client axis in O(chunk * d) memory
(PR 6): each chunk's gradients are computed and folded into the
running MAC partial in-kernel, so the client count is no longer bound
by host memory. ``--sample-rate`` adds per-round Bernoulli partial
participation and ``--client-weights datasize`` weights the aggregate
by Dirichlet shard size; with both off, behaviour (and bits) match the
resident path.

``--alpha`` is the TRUE channel tail index; ``--alpha-opt`` what the
server optimizer assumes (default: follows ``--alpha``) — set them
apart for mismatch experiments, or pass ``--track-alpha`` (==
``--alpha-opt auto``) to close the loop: the OTA kernel epilogues
reduce log-moment pilot statistics of the injected interference, the
resident slab state carries their EMA ``alpha_hat`` (checkpointed, so
``--resume`` continues the estimate bitwise), and the adaptive update
consumes it as a traced scalar each round.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --preset tiny --rounds 100
    PYTHONPATH=src python -m repro.launch.train --preset 100m --rounds 300
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.checkpoint as ckpt
from repro.configs import ARCHS, get_config, smoke_config
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, donation_report, init_train_state,
                        make_slab_round_runner, make_slab_spec,
                        run_rounds_slab)
from repro.data import dirichlet_partition, token_stream
from repro.launch.hostdev import force_host_devices
from repro.launch.mesh import make_client_mesh
from repro.models.model import ModelConfig, build_model


def preset_config(arch: str, preset: str) -> ModelConfig:
    if preset == "full":
        return get_config(arch)
    if preset == "tiny":
        return dataclasses.replace(smoke_config(arch), vocab=257)
    if preset == "100m":
        # ~100M-parameter decoder (qwen-style), the end-to-end driver size.
        return ModelConfig(
            arch=f"{arch}-100m", family="dense", n_layers=8, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=2048, vocab=8192, qk_norm=True,
            remat=False)
    raise ValueError(preset)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-14b")
    ap.add_argument("--preset", choices=["tiny", "100m", "full"],
                    default="tiny")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--client-chunk", type=int, default=None,
                    help="stream the client axis in chunks of this many "
                         "rows (per device under pallas_sharded): peak "
                         "memory O(chunk * d) instead of O(N * d); must "
                         "divide the per-device client count. Default: "
                         "resident (all clients at once)")
    ap.add_argument("--sample-rate", type=float, default=1.0,
                    help="per-round Bernoulli participation probability; "
                         "< 1 samples a client subset each round (keyed "
                         "off the round key, identical on all backends)")
    ap.add_argument("--client-weights", default="uniform",
                    choices=["uniform", "datasize"],
                    help="per-client aggregation weights: 'uniform' "
                         "(1/N, default) or 'datasize' (proportional to "
                         "the client's Dirichlet shard size)")
    ap.add_argument("--batch", type=int, default=2, help="per-client batch")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="adam_ota",
                    choices=["adam_ota", "adagrad_ota", "amsgrad_ota",
                             "yogi_ota", "fedavgm", "fedavg"])
    ap.add_argument("--backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_sharded"],
                    help="round-step backend: per-leaf jnp tree.map, the "
                         "fused Pallas slab engine (2 kernel launches/"
                         "round), or the mesh-distributed slab engine "
                         "(2 launches per DEVICE + cross-client psum)")
    ap.add_argument("--mesh", default=None,
                    help="client-mesh shape for --backend pallas_sharded, "
                         "comma-separated (e.g. '2' or '4,2', default 2); "
                         "the client count must be divisible by its product")
    ap.add_argument("--uplink", default="f32",
                    choices=["f32", "int8", "sign"],
                    help="MAC payload format: f32 is the analog uplink "
                         "(today's behaviour, bitwise); int8 quantizes each "
                         "transmitter's faded partial sum to int8 + "
                         "per-128-block f32 scales (stochastic rounding) — "
                         "~4x fewer collective bytes on the sharded MAC; "
                         "sign is the 1-bit signSGD payload with blockwise "
                         "mean-magnitude scales (deterministic, ~32x)")
    ap.add_argument("--downlink", default="f32", choices=["f32", "int8"],
                    help="model-broadcast format: f32 (default, bitwise) "
                         "or int8 (per-128-block scales + stochastic "
                         "rounding, ~4x fewer broadcast bytes; clients see "
                         "the reconstruction, the server keeps f32 master "
                         "weights)")
    ap.add_argument("--error-feedback", action="store_true",
                    help="carry each transmitter's quantization residual "
                         "across rounds and add it back before the next "
                         "quantize (needs --uplink int8 or sign); resident "
                         "in the slab state and checkpointed, so --resume "
                         "continues the residual bitwise")
    ap.add_argument("--no-interpret", action="store_true",
                    help="force-compile the Pallas kernels instead of the "
                         "platform default (auto: compiled on TPU, "
                         "interpret mode elsewhere; see also the "
                         "REPRO_PALLAS_INTERPRET env var)")
    ap.add_argument("--sign-pack", default="fold",
                    choices=["fold", "planes", "int8"],
                    help="wire container for --uplink sign: 'fold' packs "
                         "the signs into uint32 bitplanes at 1 bit/coord "
                         "(exact zeros fold to +1, all-zero blocks keep "
                         "scale 0 so the padded tail survives), 'planes' "
                         "keeps a separate nonzero-mask plane (2 bits/"
                         "coord, zeros exact), 'int8' is the PR 7 "
                         "byte-per-coord container")
    ap.add_argument("--sr-inkernel", action="store_true",
                    help="draw the int8 stochastic-rounding uniforms "
                         "inside the Pallas transmit kernel (pltpu PRNG) "
                         "instead of streaming a host-drawn f32 row "
                         "through HBM; compiled mode only (ignored under "
                         "interpret / --backend jnp), same one-block-"
                         "scale quantization contract, different uniform "
                         "stream — not bitwise vs the host-drawn path")
    ap.add_argument("--comm-buckets", type=int, default=1,
                    help="split the sharded MAC collective into this many "
                         "slab buckets, interleaved with the per-bucket "
                         "transmit epilogue (pallas_sharded only; the "
                         "overlap engine, tolerance-tier vs the default); "
                         "1 (default) keeps the single-collective graph "
                         "bitwise")
    ap.add_argument("--double-buffer", action="store_true",
                    help="two-slot pipeline for the streamed client scan: "
                         "chunk c's gradients are computed while chunk "
                         "c-1's slot folds into the accumulators (needs "
                         "--client-chunk; tolerance-tier reassociation "
                         "of the per-chunk fold)")
    ap.add_argument("--ckpt-async", action="store_true",
                    help="write checkpoints on a background thread: the "
                         "host snapshot is taken synchronously (safe "
                         "under donation), the npz encode + atomic "
                         "rename overlap training; files are bitwise "
                         "identical to the blocking path and all writes "
                         "are joined at loop exit")
    ap.add_argument("--no-donate", action="store_true",
                    help="keep a second resident copy of the slab state "
                         "across the scan dispatch instead of donating "
                         "the input slabs to the compiled runner "
                         "(donation is safe here: run_rounds_slab "
                         "threads the state linearly)")
    ap.add_argument("--donation-report", action="store_true",
                    help="before training, lower+compile the round runner "
                         "and print how many donated input bytes the "
                         "executable actually aliases to outputs "
                         "(verifies the slabs are updated in place, not "
                         "copied)")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=1.5,
                    help="TRUE tail index of the channel's alpha-stable "
                         "interference (what the simulator injects)")
    ap.add_argument("--alpha-opt", default=None,
                    help="tail index the server OPTIMIZER assumes: a float "
                         "(fixed assumption — set != --alpha for mismatch "
                         "experiments) or 'auto' (closed-loop online "
                         "estimation from the fused pilot statistics). "
                         "Default: 'auto' under --track-alpha, else "
                         "--alpha (matched, the old conflated behaviour)")
    ap.add_argument("--track-alpha", action="store_true",
                    help="shorthand for --alpha-opt auto: estimate the "
                         "interference tail index online (log-moment "
                         "stats fused into the OTA kernel epilogue, EMA "
                         "resident in the slab state, checkpointed) and "
                         "feed it back into the adaptive update")
    ap.add_argument("--xi-scale", type=float, default=0.05)
    ap.add_argument("--dir", type=float, default=0.5,
                    help="Dirichlet concentration (data heterogeneity)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in --ckpt-dir "
                         "(bitwise-identical continuation: round keys are "
                         "derived from the absolute round index)")
    ap.add_argument("--scan-rounds", type=int, default=8,
                    help="rounds fused into one jax.lax.scan dispatch over "
                         "the resident slab state (clipped to log/ckpt "
                         "boundaries)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")
    if args.scan_rounds < 1:
        ap.error("--scan-rounds must be >= 1")

    # Resolve the optimizer's assumed alpha: --track-alpha and
    # --alpha-opt auto are synonyms; a bare float pins the assumption
    # (mismatch scenarios); unset follows the true channel alpha.
    if args.alpha_opt is None:
        alpha_opt = "auto" if args.track_alpha else args.alpha
    elif args.alpha_opt == "auto":
        alpha_opt = "auto"
    else:
        try:
            alpha_opt = float(args.alpha_opt)
        except ValueError:
            ap.error(f"--alpha-opt must be a float or 'auto', "
                     f"got {args.alpha_opt!r}")
        if args.track_alpha:
            ap.error("--track-alpha conflicts with a fixed --alpha-opt "
                     f"{alpha_opt}; drop one of the two")
    track = alpha_opt == "auto"

    mesh = None
    if args.mesh is not None and args.backend != "pallas_sharded":
        ap.error(f"--mesh only applies to --backend pallas_sharded "
                 f"(got --backend {args.backend}); it would be silently "
                 f"ignored on a single-device backend")
    if args.backend == "pallas_sharded":
        try:
            mesh_shape = tuple(int(x) for x in (args.mesh or "2").split(","))
            if not mesh_shape or any(s < 1 for s in mesh_shape):
                raise ValueError
        except ValueError:
            ap.error(f"--mesh must be comma-separated positive ints "
                     f"(e.g. '2' or '4,2'), got {args.mesh!r}")
        # A CPU host exposes one device; force enough host devices for
        # the mesh BEFORE jax initialises its backend (first jax array
        # op locks the count).
        force_host_devices(math.prod(mesh_shape))

    cfg = preset_config(args.arch, args.preset)
    model = build_model(cfg)
    print(f"arch={cfg.arch} params={cfg.n_params()/1e6:.1f}M "
          f"vocab={cfg.vocab} clients={args.clients} "
          f"alpha={args.alpha} alpha_opt={alpha_opt}")

    # Client corpora: one shared stream, Dirichlet-partitioned by "domain"
    # id so clients see different mixtures (non-iid).
    toks = token_stream(2_000_000, vocab=cfg.vocab, seed=args.seed)
    n_windows = (len(toks) - args.seq - 1) // args.seq
    starts_all = np.arange(n_windows) * args.seq
    domain = (starts_all // (len(toks) // 16)).astype(np.int64)  # 16 domains
    parts = dirichlet_partition(domain, args.clients, args.dir,
                                seed=args.seed, min_per_client=args.batch)

    def batch_fn(t, key):
        # Keyed by the ABSOLUTE round index (not by call count): a
        # resumed process must draw the same batches for round t as the
        # uninterrupted one, or --resume could not be bitwise-identical.
        rng = np.random.default_rng((args.seed, t))
        out = np.empty((args.clients, args.batch, args.seq), np.int32)
        for c, p in enumerate(parts):
            pick = rng.choice(p, size=args.batch, replace=len(p) < args.batch)
            for j, w in enumerate(pick):
                s = starts_all[w]
                out[c, j] = toks[s:s + args.seq]
        return {"tokens": jnp.asarray(out)}

    # None = auto-select from the platform (compiled on TPU only);
    # --no-interpret pins compiled mode explicitly.
    interpret = False if args.no_interpret else None
    if args.error_feedback and args.uplink == "f32":
        ap.error("--error-feedback needs a quantized uplink "
                 "(--uplink int8 or sign); the f32 payload has no residual")
    if args.sr_inkernel and args.uplink != "int8":
        ap.error("--sr-inkernel applies to the stochastically rounded "
                 f"int8 uplink only (got --uplink {args.uplink})")
    if args.comm_buckets < 1:
        ap.error("--comm-buckets must be >= 1")
    if args.comm_buckets > 1 and args.backend != "pallas_sharded":
        ap.error("--comm-buckets > 1 buckets the sharded MAC collective; "
                 f"it needs --backend pallas_sharded (got {args.backend})")
    if args.double_buffer and args.client_chunk is None:
        ap.error("--double-buffer pipelines the streamed client scan; "
                 "it needs --client-chunk")
    ch = OTAChannelConfig(alpha=args.alpha, xi_scale=args.xi_scale,
                          backend=args.backend, interpret=interpret,
                          uplink=UplinkConfig(
                              mode=args.uplink,
                              error_feedback=args.error_feedback,
                              sign_pack=args.sign_pack,
                              sr_inkernel=args.sr_inkernel),
                          downlink=args.downlink,
                          comm_buckets=args.comm_buckets)
    ad = AdaptiveConfig(optimizer=args.optimizer, lr=args.lr,
                        alpha=alpha_opt, beta2=0.3, backend=args.backend,
                        interpret=interpret)
    n_shards = 1
    if args.backend == "pallas_sharded":
        mesh = make_client_mesh(mesh_shape)
        n_shards = math.prod(mesh_shape)
        print(f"client mesh {dict(mesh.shape)} "
              f"({len(jax.devices())} devices visible)")
    weights = None
    if args.client_weights == "datasize":
        weights = tuple(float(len(p)) for p in parts)
    fl = FLConfig(n_clients=args.clients, client_chunk=args.client_chunk,
                  sample_rate=args.sample_rate, client_weights=weights,
                  double_buffer=args.double_buffer)
    # The driver threads the state linearly through run_rounds_slab, so
    # donating the slabs is safe by construction: each chunk's output
    # state is the only live reference to the next chunk's input.
    run_chunk = make_slab_round_runner(lambda p, b: model.loss_fn(p, b), ch,
                                       ad, fl, mesh=mesh,
                                       donate=not args.no_donate)
    params = model.init(jax.random.key(args.seed))
    spec = make_slab_spec(params, shards=n_shards)
    state = init_train_state(ad, params, spec=spec,
                             error_feedback=args.error_feedback)
    del params   # resident from here on; pytrees only at boundaries

    start_round = 0
    if args.resume:
        latest = ckpt.latest_round(args.ckpt_dir)
        if latest is None:
            print(f"no checkpoint under {args.ckpt_dir}; starting fresh")
        else:
            state, _ = ckpt.load_slab_state(latest, spec)
            start_round = int(state.step)
            print(f"resumed from {latest} at round {start_round}")
            # Reconcile the EF slab with this run's flags: a pre-EF (or
            # EF-off) checkpoint resumed WITH --error-feedback starts
            # the residual loop fresh (zeros); an EF checkpoint resumed
            # WITHOUT the flag drops the carried residual.
            if args.error_feedback and state.ef is None:
                print("checkpoint carries no error-feedback residual; "
                      "starting the EF loop from zeros")
                state = dataclasses.replace(
                    state, ef=jnp.zeros((spec.shards, spec.padded),
                                        jnp.float32))
            elif not args.error_feedback and state.ef is not None:
                print("checkpoint carries an error-feedback residual but "
                      "--error-feedback is off; dropping it")
                state = dataclasses.replace(state, ef=None)

    base_key = jax.random.key(args.seed + 1)

    if args.donation_report and start_round < args.rounds:
        r = min(args.scan_rounds, args.rounds - start_round)
        ks = jnp.stack([jax.random.fold_in(base_key, start_round + i)
                        for i in range(r)])
        bs = [batch_fn(start_round + i, None) for i in range(r)]
        ex = jax.tree.map(lambda *xs: jnp.stack(xs), *bs)
        rep = donation_report(run_chunk, state, ks, ex)
        if rep["supported"]:
            print(f"donation: {rep['aliased_bytes']:,} / "
                  f"{rep['donated_bytes']:,} state bytes aliased "
                  f"in-place ({len(rep['aliased_pairs'] or [])} buffers)")
        else:
            print("donation: memory analysis not exposed on this backend; "
                  "aliasing unverified")

    t0 = time.time()

    def chunk_hook(t, st, history):
        # run_rounds_slab clips chunks to the align periods, so every
        # log/checkpoint multiple lands exactly on a chunk boundary.
        if args.log_every and t % args.log_every == 0:
            rec = history[-1]
            dt = time.time() - t0
            a_col = (f"  a^ {rec['alpha_hat']:.3f}" if track else "")
            print(f"round {t:5d}  loss {rec['loss']:.4f}  "
                  f"|g| {rec['grad_norm']:.3e}{a_col}  "
                  f"({dt / (t - start_round):.2f}s/round)", flush=True)
        if args.ckpt_dir and args.ckpt_every and t % args.ckpt_every == 0:
            ckpt.save_slab_state(os.path.join(args.ckpt_dir,
                                              f"round_{t}.npz"), st,
                                 blocking=not args.ckpt_async)

    state, history = run_rounds_slab(
        run_chunk, state, None, batch_fn, args.rounds,
        chunk=args.scan_rounds,
        key_fn=lambda t: jax.random.fold_in(base_key, t),
        start_round=start_round, chunk_hook=chunk_hook,
        align=(args.log_every, args.ckpt_every if args.ckpt_dir else 0))
    if args.ckpt_async:
        ckpt.wait_for_async_saves()
    if args.history_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.history_out)),
                    exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(history, f)
    if history:
        a_col = (f"  alpha_hat {history[-1]['alpha_hat']:.4f} "
                 f"(true {args.alpha})" if track else "")
        print(f"done: final loss {history[-1]['loss']:.4f} "
              f"(started {history[0]['loss']:.4f}){a_col}")
    else:
        print(f"done: nothing to do (resumed at round {start_round} "
              f">= --rounds {args.rounds})")


if __name__ == "__main__":
    main()
