"""Force CPU host devices for multi-device runs — jax-free on purpose.

jax locks the host device count at first backend init, so subprocess
entry points (``repro.launch.shard_check``, ``benchmarks.shard_bench``)
must append ``--xla_force_host_platform_device_count`` to ``XLA_FLAGS``
before any jax array/device op. They read the requested mesh shapes
from raw ``sys.argv`` because argparse would come too late (it runs
after the jax imports at module top). Harmless on real TPU hosts — the
flag only affects the Host platform.
"""

from __future__ import annotations

import argparse
import os
from typing import Optional, Sequence

DEFAULT_HOST_DEVICES = 8
HOST_DEVICES_ENV = "REPRO_HOST_DEVICES"
HOST_DEVICES_FLAG = "--host-devices"


def host_device_override(argv: Optional[Sequence[str]] = None) -> int:
    """The configured forced host-device floor: ``--host-devices N``
    in ``argv`` (both ``--host-devices 16`` and ``--host-devices=16``)
    wins over the ``REPRO_HOST_DEVICES`` environment variable, which
    wins over ``DEFAULT_HOST_DEVICES``. Malformed values fall through
    (argparse / the caller reports them properly later); this runs
    before any jax import, so it must never raise on user input."""
    n = DEFAULT_HOST_DEVICES
    env = os.environ.get(HOST_DEVICES_ENV)
    if env is not None:
        try:
            n = max(1, int(env))
        except ValueError:
            pass
    for i, a in enumerate(argv or ()):
        v = None
        if a == HOST_DEVICES_FLAG and i + 1 < len(argv):
            v = argv[i + 1]
        elif a.startswith(HOST_DEVICES_FLAG + "="):
            v = a[len(HOST_DEVICES_FLAG) + 1:]
        if v is not None:
            try:
                n = max(1, int(v))
            except ValueError:
                pass
    return n


def mesh_device_count(argv: Sequence[str], flag: str,
                      minimum: Optional[int] = None) -> int:
    """Max product over the comma-separated mesh shapes given by
    ``flag`` in ``argv`` — both the ``--mesh 4,2`` / ``--meshes 2 4,2``
    and the ``--mesh=4,2`` forms — floored at ``minimum``. ``minimum``
    defaults to ``host_device_override(argv)`` (the ``--host-devices``
    flag / ``REPRO_HOST_DEVICES`` env var, else 8), so parity checks
    can simulate wider meshes for streamed-client runs without editing
    code. Absent or malformed values fall back to ``minimum``; argparse
    reports the malformed ones properly later."""
    argv = list(argv)
    if minimum is None:
        minimum = host_device_override(argv)
    vals = []
    for i, a in enumerate(argv):
        if a == flag:
            for v in argv[i + 1:]:
                if v.startswith("--"):
                    break
                vals.append(v)
        elif a.startswith(flag + "="):
            vals.append(a[len(flag) + 1:])
    n_max = minimum
    for v in vals:
        try:
            n = 1
            for x in v.split(","):
                n *= int(x)
            n_max = max(n_max, n)
        except ValueError:
            pass
    return n_max


def force_host_devices(n: int) -> None:
    """Append the host-device override to ``XLA_FLAGS``. Call before
    jax's first backend init (first array/device op)."""
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}").strip()


def positive_int(v: str) -> int:
    """argparse type: int >= 1."""
    n = int(v)
    if n < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {n}")
    return n
