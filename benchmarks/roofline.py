"""Roofline analysis from the dry-run AND slab-engine bench artifacts.

Reads results/dryrun/*.json (written by repro.launch.dryrun), derives the
three roofline terms per (arch x shape) on the single-pod mesh, and emits
the §Roofline markdown table.

    compute    = FLOPs_per_device / 197e12        (v5e bf16 peak)
    memory     = bytes_per_device / 819e9         (HBM bw)
    collective = collective_bytes_per_device / 4.9e10  (~ICI link bw)

FLOPs/bytes/collective-bytes come from the depth-CALIBRATED measurements
(XLA counts scan bodies once; dryrun extrapolates from unrolled depth-2/4
compiles — see launch/dryrun.py:calibrate).

**Slab-engine grading** (PR 8, ``--bench`` / ``grade_bench``): the
tracked BENCH_round_step.json / BENCH_train_loop.json artifacts carry
per-round HBM- and comms-byte models next to measured wall time. This
module turns each record's byte model into its v5e roofline floor
(``hbm_bytes / HBM_BW``, ``comms_bytes / ICI_BW``), names the binding
term, and — ONLY when the record was produced by compiled kernels —
grades the measured ``us_per_round`` against that floor (attainment =
floor / measured). Interpret-mode wall clock is a Python-loop artifact,
so records whose ``interpret`` provenance (the PR 8 stamp; absent means
the pre-PR 8 CPU container, treated as interpret) resolves true keep
their byte model and floor but get no attainment grade — the gate that
stops a CPU CI run from "failing the roofline".
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 4.9e10           # bytes/s per link (~50 GB/s)

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BENCH_FILES = ("BENCH_round_step.json", "BENCH_train_loop.json")


def load_records(path_glob: str = "results/dryrun/*.json") -> List[Dict]:
    """Load dry-run records; when the same (arch, shape, mesh, knobs) was
    re-run (e.g. a fix re-measurement in a later file), the later OK
    record supersedes the earlier one."""
    recs = []
    for p in sorted(glob.glob(path_glob)):
        with open(p) as f:
            data = json.load(f)
        recs.extend(data if isinstance(data, list) else [data])
    by_key: Dict = {}
    for r in recs:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("optimizer"), r.get("fsdp"), r.get("shard_cache_seq"),
               r.get("state_dtype"), json.dumps(r.get("overrides", {}),
                                                sort_keys=True))
        prev = by_key.get(key)
        if prev is None or (r.get("ok") and not prev.get("ok")):
            by_key[key] = r
    return list(by_key.values())


def terms(rec: Dict) -> Optional[Dict]:
    cal = rec.get("calibrated")
    if not rec.get("ok") or not cal:
        return None
    t_c = cal["flops"] / PEAK_FLOPS
    t_m = cal["bytes_accessed"] / HBM_BW
    t_x = cal["collective_bytes"] / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    # MODEL_FLOPS: 6·N·D training, 2·N·D forward (prefill), 2·N per token
    # (decode); N = active params.
    n_act = rec["n_active_params"]
    shape = rec["shape"]
    chips = 512 if rec["mesh"] == "multi" else 256
    from repro.launch.specs import INPUT_SHAPES
    sh = INPUT_SHAPES[shape]
    if sh["kind"] == "train":
        model_flops = 6 * n_act * sh["seq"] * sh["batch"]
    elif sh["kind"] == "prefill":
        model_flops = 2 * n_act * sh["seq"] * sh["batch"]
    else:
        model_flops = 2 * n_act * sh["batch"]          # one token per seq
    model_flops_dev = model_flops / chips
    useful = model_flops_dev / cal["flops"] if cal["flops"] else float("nan")
    return dict(
        arch=rec["arch"], shape=shape, mesh=rec["mesh"],
        compute_s=t_c, memory_s=t_m, collective_s=t_x, dominant=dominant,
        model_flops=model_flops, model_flops_per_device=model_flops_dev,
        hlo_flops_per_device=cal["flops"],
        useful_ratio=useful,
        collectives=cal["collectives"],
        memory_bytes=rec.get("memory", {}),
    )


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful (6ND/HLO) |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        t = terms(r)
        if t is None or t["mesh"] != mesh:
            continue
        rows.append(
            f"| {t['arch']} | {t['shape']} | {_fmt_s(t['compute_s'])} "
            f"| {_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} "
            f"| **{t['dominant']}** | {t['useful_ratio']:.2f} |")
    return "\n".join(rows)


def pick_hillclimb_targets(recs: List[Dict]) -> Dict[str, Dict]:
    """worst roofline fraction (compute / max term), most collective-bound,
    most representative of the paper's technique (train_4k — where the OTA
    gradient path and ADOTA update actually run)."""
    ts = [t for t in (terms(r) for r in recs)
          if t is not None and t["mesh"] == "single"]
    def frac(t):
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return t["compute_s"] / total if total else 1.0
    worst = min(ts, key=frac)
    coll = max(ts, key=lambda t: t["collective_s"]
               / max(t["compute_s"] + t["memory_s"], 1e-12))
    train = [t for t in ts if t["shape"] == "train_4k"]
    rep = max(train, key=lambda t: t["model_flops"]) if train else worst
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def load_bench_payloads(root: str = REPO_ROOT) -> Dict[str, Dict]:
    """The tracked slab-engine artifacts, ``{filename: {"meta", "records"}}``
    (missing files are skipped — a fresh clone before the first full
    bench run has none)."""
    out = {}
    for fn in BENCH_FILES:
        p = os.path.join(root, fn)
        if os.path.exists(p):
            with open(p) as f:
                out[fn] = json.load(f)
    return out


def _record_interpret(rec: Dict, meta: Optional[Dict]) -> bool:
    """Resolved interpret provenance of a bench record. Preference
    order: the record's own PR 8 ``interpret`` stamp, then the meta
    config's; records predating the stamp came from the interpret-mode
    CPU container, so absent means True — never grade unlabelled wall
    clock against a TPU roofline."""
    stamp = rec.get("interpret")
    if isinstance(stamp, dict) and "resolved" in stamp:
        return bool(stamp["resolved"])
    if meta:
        cfg = meta.get("config", {})
        if "interpret" in cfg:
            return bool(cfg["interpret"])
    return True


def grade_record(rec: Dict, meta: Optional[Dict] = None) -> Optional[Dict]:
    """One slab-engine bench record -> its roofline grade, or None for
    records with no byte model (e.g. the streamed clients/sec rows).

    Always derived from the byte models: the HBM and comms floors and
    which one binds. Derived from wall time ONLY in compiled mode:
    ``attainment`` (floor / measured — 1.0 means the engine runs at the
    roofline) and ``headroom_x`` (its inverse). Interpret-mode records
    report ``measured_valid: False`` with both grades None.
    """
    hbm = rec.get("hbm_bytes_est")
    if hbm is None:
        return None
    comms = rec.get("comms_bytes_per_round", 0) or 0
    hbm_s = hbm / HBM_BW
    comms_s = comms / ICI_BW
    floor_s = max(hbm_s, comms_s)
    bound = "hbm" if hbm_s >= comms_s else "comms"
    interpret = _record_interpret(rec, meta)
    measured_s = rec.get("us_per_round", 0.0) * 1e-6
    grade = dict(
        name=rec["name"], backend=rec.get("backend"),
        n_params=rec.get("n_params"), uplink=rec.get("uplink"),
        hbm_floor_s=hbm_s, comms_floor_s=comms_s, floor_s=floor_s,
        bound=bound, interpret=interpret,
        measured_valid=not interpret, measured_s=measured_s,
        attainment=None, headroom_x=None)
    if not interpret and measured_s > 0 and floor_s > 0:
        grade["attainment"] = floor_s / measured_s
        grade["headroom_x"] = measured_s / floor_s
    return grade


def grade_bench(payloads: Optional[Dict[str, Dict]] = None) -> List[Dict]:
    """Grade every byte-model-carrying record in the tracked BENCH
    artifacts against the v5e roofline constants."""
    if payloads is None:
        payloads = load_bench_payloads()
    grades = []
    for fn, payload in sorted(payloads.items()):
        meta = payload.get("meta")
        for rec in payload.get("records", []):
            g = grade_record(rec, meta)
            if g is not None:
                g["source"] = fn
                grades.append(g)
    return grades


def markdown_bench_table(grades: List[Dict]) -> str:
    rows = ["| record | hbm floor | comms floor | bound | attainment |",
            "|---|---|---|---|---|"]
    for g in grades:
        att = (f"{g['attainment']:.2f}" if g["attainment"] is not None
               else "n/a (interpret)")
        rows.append(f"| {g['name']} | {_fmt_s(g['hbm_floor_s'])} "
                    f"| {_fmt_s(g['comms_floor_s'])} | **{g['bound']}** "
                    f"| {att} |")
    return "\n".join(rows)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", action="store_true",
                    help="grade the tracked BENCH_*.json slab-engine "
                         "records against the v5e roofline instead of "
                         "the model-zoo dryrun artifacts")
    ap.add_argument("--bench-root", default=REPO_ROOT,
                    help="directory holding the BENCH_*.json artifacts")
    args = ap.parse_args()
    if args.bench:
        grades = grade_bench(load_bench_payloads(args.bench_root))
        if not grades:
            print("no BENCH_*.json artifacts found; run "
                  "`python -m benchmarks.run --only round_step` first")
            return
        print(markdown_bench_table(grades))
        n_graded = sum(1 for g in grades if g["attainment"] is not None)
        print(f"\n{len(grades)} records, {n_graded} wall-clock graded "
              f"({len(grades) - n_graded} interpret-mode: byte models "
              f"only)")
        return
    recs = load_records()
    print(markdown_table(recs, "single"))
    print()
    targets = pick_hillclimb_targets(recs)
    for k, t in targets.items():
        print(f"{k}: {t['arch']} x {t['shape']} (dominant {t['dominant']})")


if __name__ == "__main__":
    main()
