"""End-to-end federated system behaviour (the paper's claims, CPU-sized)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step, run_rounds)
from repro.data import FederatedBatcher, gaussian_mixture
from repro.models.vision import accuracy, logistic_regression


def _train(optimizer, *, alpha=1.5, scale=0.3, n_clients=20, rounds=60,
           lr=0.05, dir_alpha=0.5, seed=0, beta2=0.3):
    data = gaussian_mixture(4000, 16, 5, seed=seed)
    model = logistic_regression(16, 5)
    batcher = FederatedBatcher(data, n_clients, 16, dir_alpha=dir_alpha,
                               seed=seed)
    ch = OTAChannelConfig(alpha=alpha, xi_scale=scale)
    ad = AdaptiveConfig(optimizer=optimizer, lr=lr, alpha=alpha, beta2=beta2)
    rs = make_round_step(model.loss_fn, ch, ad, FLConfig(n_clients=n_clients))
    params = model.init(jax.random.key(seed))
    state = init_server(params, ad)

    def batch_fn(t, key):
        b = batcher(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    params, state, hist = run_rounds(rs, params, state, jax.random.key(seed),
                                     batch_fn, rounds)
    final_loss = float(np.mean([h["loss"] for h in hist[-10:]]))
    acc = accuracy(model, params, jnp.asarray(data.x), data.y)
    return final_loss, acc, hist


def test_adota_trains_under_heavy_tail():
    loss, acc, hist = _train("adam_ota")
    assert hist[0]["loss"] > loss          # it learns
    assert acc > 0.75                      # separable mixture


def test_adota_beats_fedavgm_under_impulsive_noise():
    """Paper Fig. 2: under alpha=1.5 interference the adaptive methods
    dominate FedAvgM at matched lr. The separation grows with the
    interference scale (at 0.5 both still reach ~0.93 on this easy
    mixture and the gap is ~0.02); 1.5 is squarely in the impulsive
    regime the figure shows, where the gap is ~0.10."""
    _, acc_adam, _ = _train("adam_ota", scale=1.5)
    _, acc_avgm, _ = _train("fedavgm", scale=1.5, lr=0.02)
    assert acc_adam > acc_avgm + 0.05


def test_lighter_tails_converge_better():
    """Paper Fig. 5 / Remark 6: larger alpha (lighter tail) -> lower loss,
    on AdaGrad-OTA."""
    loss_heavy, _, _ = _train("adagrad_ota", alpha=1.2, rounds=50, seed=3)
    loss_light, _, _ = _train("adagrad_ota", alpha=1.9, rounds=50, seed=3)
    assert loss_light < loss_heavy


def test_more_clients_help():
    """Paper Fig. 6 / Remark 12: larger N reduces the channel damage."""
    loss_few, _, _ = _train("adagrad_ota", n_clients=4, scale=0.5, seed=5)
    loss_many, _, _ = _train("adagrad_ota", n_clients=40, scale=0.5, seed=5)
    assert loss_many < loss_few


def test_local_steps_pseudo_gradient():
    """FedAvg-style multi-step CLIENTUPDATE also trains."""
    data = gaussian_mixture(2000, 16, 5, seed=1)
    model = logistic_regression(16, 5)
    fl = FLConfig(n_clients=8, local_steps=3, local_lr=0.1)
    batcher = FederatedBatcher(data, 8, 8, dir_alpha=0.5, local_steps=3,
                               seed=1)
    ch = OTAChannelConfig(alpha=1.8, xi_scale=0.05)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.8)
    rs = make_round_step(model.loss_fn, ch, ad, fl)
    params = model.init(jax.random.key(0))
    state = init_server(params, ad)

    def batch_fn(t, key):
        b = batcher(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    params, state, hist = run_rounds(rs, params, state, jax.random.key(0),
                                     batch_fn, 40)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8


def test_tail_index_estimation_in_the_loop():
    """Remark 3 integration: estimate alpha from an interference probe and
    run ADOTA with the ESTIMATED alpha — must still train."""
    from repro.core import sample_interference
    from repro.core.tail_index import log_moment_estimate
    true_cfg = OTAChannelConfig(alpha=1.5, xi_scale=0.3)
    probe = sample_interference(jax.random.key(42), true_cfg, (50_000,))
    a_hat, _ = log_moment_estimate(probe)
    assert abs(float(a_hat) - 1.5) < 0.1
    loss, acc, _ = _train("adam_ota", alpha=float(a_hat), scale=0.3)
    assert acc > 0.7
