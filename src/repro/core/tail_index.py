"""On-line estimation of the interference tail index alpha (paper Remark 3).

The ADOTA update needs alpha both for the |Delta|^alpha accumulator and the
alpha-root stepsize. The paper points to moment-type estimators for
multivariate alpha-stable laws [42]; we implement the classic *log-moment*
estimator (Ma & Nikias, 1995), which is simple, consistent, jit-able and
needs only samples of the interference (e.g. measured on a quiet
sub-carrier between rounds):

For X ~ S(alpha, beta=0, c, 0):

    E[log|X|]   = euler_gamma * (1/alpha - 1) + log c
    Var[log|X|] = (pi^2 / 6) * (1/alpha^2 + 1/2)

so  1/alpha^2 = 6 * Var[log|X|] / pi^2 - 1/2, clipped into alpha in (1, 2].
A Hill-type order-statistics estimator is provided as a cross-check.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

_EULER = 0.5772156649015329


def log_moment_estimate(samples: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Estimate (alpha, scale) of a symmetric alpha-stable law.

    Args:
      samples: 1-D array of i.i.d. draws (any float dtype).

    Returns:
      (alpha_hat, scale_hat), clipped to alpha in (1.01, 2.0].
    """
    x = jnp.abs(samples.astype(jnp.float32).reshape(-1))
    x = jnp.maximum(x, jnp.finfo(jnp.float32).tiny)
    lx = jnp.log(x)
    mean, var = jnp.mean(lx), jnp.var(lx)
    inv_a2 = jnp.maximum(6.0 * var / (math.pi**2) - 0.5, 1e-6)
    alpha = jnp.clip(1.0 / jnp.sqrt(inv_a2), 1.01, 2.0)
    scale = jnp.exp(mean - _EULER * (1.0 / alpha - 1.0))
    return alpha, scale


def hill_estimate(samples: jax.Array, k_frac: float = 0.05) -> jax.Array:
    """Hill estimator of the tail index from the upper order statistics.

    alpha_hat = k / sum_{i<k} (log X_(i) - log X_(k)) over the k largest
    |samples|. Static ``k = max(8, k_frac * n)``. Biased for stable laws at
    moderate n (the stable tail is only asymptotically Pareto) — used as a
    sanity cross-check of the log-moment estimator, not in the optimizer.
    """
    x = jnp.abs(samples.astype(jnp.float32).reshape(-1))
    n = x.shape[0]
    k = max(8, int(k_frac * n))
    top = jax.lax.top_k(x, k + 1)[0]
    top = jnp.maximum(top, jnp.finfo(jnp.float32).tiny)
    logs = jnp.log(top)
    alpha = k / jnp.sum(logs[:k] - logs[k])
    return jnp.clip(alpha, 0.5, 4.0)


def estimate_from_gradient_residual(g_clean: jax.Array, g_noisy: jax.Array
                                    ) -> Tuple[jax.Array, jax.Array]:
    """Estimate alpha from the residual of a known-clean reference gradient.

    In deployments where a narrowband pilot round is possible, the server
    can difference a digitally-verified gradient against the OTA one; the
    residual is (approximately) the interference vector.
    """
    return log_moment_estimate((g_noisy - g_clean).reshape(-1))
