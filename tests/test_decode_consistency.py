"""Serving correctness: prefill + one-token decode must reproduce the
full-sequence forward logits (f32, all 10 architecture families)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models.model import build_model

B, S = 2, 24


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_forward(arch):
    kw = {}
    cfg = smoke_config(arch)
    if cfg.n_experts:
        kw["capacity_factor"] = 4.0   # lossless dispatch for exactness
    cfg = dataclasses.replace(cfg, param_dtype="float32", **kw)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0, cfg.vocab)
    bf = {"tokens": toks}
    bp = {"tokens": toks[:, :S]}
    if cfg.family == "encdec":
        ae = jax.random.normal(jax.random.key(3), (B, cfg.enc_seq, cfg.d_model))
        bf["audio_embed"] = ae
        bp["audio_embed"] = ae
    if cfg.family == "vlm":
        ie = jax.random.normal(jax.random.key(3),
                               (B, cfg.n_img_tokens, cfg.d_model))
        bf["image_embed"] = ie
        bp["image_embed"] = ie
    logits_full, _ = model.forward(params, bf)
    pl, cache = model.prefill(params, bp, length=S + cfg.n_meta_tokens + 8)
    dl, _ = model.decode_step(params, cache, toks[:, S:S + 1], jnp.asarray(S))

    def rel(a, b):
        return float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))

    assert rel(pl[:, 0], logits_full[:, S - 1]) < 2e-4
    assert rel(dl[:, 0], logits_full[:, S]) < 2e-4


def test_multi_token_greedy_decode_matches_forward():
    """Decode 6 tokens autoregressively (teacher-forced) == forward."""
    cfg = dataclasses.replace(smoke_config("qwen3-14b"),
                              param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    total = S + 6
    toks = jax.random.randint(jax.random.key(2), (B, total), 0, cfg.vocab)
    logits_full, _ = model.forward(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :S]}, length=total)
    for t in range(S, total):
        dl, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.asarray(t))
        err = float(jnp.max(jnp.abs(dl[:, 0] - logits_full[:, t])))
        assert err / (float(jnp.max(jnp.abs(logits_full[:, t]))) + 1e-9) < 2e-4


def test_ring_cache_window_decode():
    """Sliding-window arch (ring KV cache shorter than the sequence):
    decode with an O(window) cache matches forward with window masking."""
    cfg = dataclasses.replace(smoke_config("starcoder2-15b"),
                              param_dtype="float32", window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    total = 20
    toks = jax.random.randint(jax.random.key(2), (B, total), 0, cfg.vocab)
    logits_full, _ = model.forward(params, {"tokens": toks})
    pre = 12
    _, cache = model.prefill(params, {"tokens": toks[:, :pre]}, length=8)
    # ring cache is window-sized
    assert cache["layers"]["kv"]["k"].shape[2] == 8
    for t in range(pre, total):
        dl, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.asarray(t))
        rel = float(jnp.max(jnp.abs(dl[:, 0] - logits_full[:, t]))
                    / (jnp.max(jnp.abs(logits_full[:, t])) + 1e-9))
        assert rel < 2e-4, (t, rel)
