"""Sharded slab engine: the OTA round distributed over a device mesh.

The paper's aggregation (Eq. 7) is a *physical superposition*: all N
clients transmit simultaneously and the channel adds their signals.
This module maps that superposition onto a device mesh — the mesh IS
the multiple-access channel — and keeps the training state *resident*
as sharded slabs across rounds (true ZeRO: each device permanently owns
one contiguous ``spec.shard_len`` slice of the parameter slab and of
every optimizer-state slab; optimizer state never moves between
devices).

Steady-state round, per device (``make_shard_slab_step`` /
``make_shard_slab_runner``):

1. ``all_gather`` the parameter slices -> the full (padded,) slab, once
   per round — the server's model *broadcast* to the clients (the only
   full-model collective left in the loop; ~1 slab of ring traffic vs
   the 2(k+1) slabs the PR-2 masked-psum regather moved).
2. The device's N/P local clients compute gradients on the materialised
   pytree; ONE fused ``ota_transmit_slab`` launch forms the faded
   partial sum ``(1/N) sum_{n local} h_n G_n`` over the full slab
   width (at ``uplink="int8"`` the launch ends in the quantize-on-write
   epilogue: int8 payload + one f32 scale per 128 entries).
3. The MAC superposition: at ``uplink="f32"`` a ``psum_scatter``
   completes the MAC *and* delivers each device only its own slab slice
   (half the ring traffic of the full psum the PR-2 path used, no
   full-width result anywhere). At ``uplink="int8"`` the wire carries
   the quantized payloads instead — an ``all_to_all`` hands every
   device the P payload blocks addressed to its slice (~4x fewer bytes;
   int8 codewords with per-transmitter scales cannot be summed on the
   wire, so the reduction happens after dequantization in step 4).
4. The receive stage, on this slice only: dequantize + superpose the P
   payload rows (int8; f32 arrives already summed) and inject the CMS
   interference. The (u, e) draws are made at full width from the SAME
   per-leaf keying as the single-device backends (PRNG is compute, not
   communication), then sliced; the branch-free CMS transform runs on
   the slice only.
5. ONE fused ``adaptive_update_slab`` launch updates the device's
   resident w/Delta/nu slices in place. Nothing is regathered: the
   next round starts from the slices.

``RoundMetrics`` norms are computed from per-slice squared sums
(``sqrt(psum(sum(slice**2)))``) — no full-width tensor is ever formed
for a metric.

**Per-shard PRNG keying contract** (unchanged from PR 2). Every random
draw is made from the round key with the exact keying of the
single-device path and then *sliced*, never re-keyed per shard:

* fading: ``kh, kx = split(key)``; ``h = sample_fading(kh, cfg, (N,))``
  is the full draw on every shard; shard s uses rows
  ``h[s*N/P : (s+1)*N/P]`` (clients are laid out in linear shard-index
  order, matching the batch sharding).
* interference: ``(u, e) = _cms_slab_inputs(kx, spec)`` draws per LEAF
  (``fold_in(kx, leaf_index)``), so the values of every real slab entry
  are independent of the padded length — specs built with different
  ``shards`` (hence different padding) agree on every real entry.
* stochastic rounding (``uplink="int8"`` only):
  ``uplink_sr_slab_inputs(key, spec, shard_index)`` — per TRANSMITTER
  (each device quantizes a different partial sum), the single-device
  engines being transmitter 0, so the (1,)-mesh consumes exactly the
  single-device draws.

Hence jnp, pallas and pallas_sharded consume literally the same noise
and at ``uplink="f32"`` differ only by float32 summation order
(reduce-scatter of P partial sums vs one in-kernel reduction) —
multi-round trajectory parity holds to ~1e-7 relative, tested at 1e-5
over >= 5 rounds (tests/test_shard_roundstep.py,
repro.launch.shard_check). At ``uplink="int8"`` quantization is
per-transmitter, so P-shard trajectories agree with the single-device
quantized engines to quantization-error order (one int8 quantum per
payload entry per round), not f32 rounding — tested with error bounds
(tests/test_uplink.py, ``shard_check --uplink int8``); the (1,)-mesh
remains bitwise-equal to the single-device pallas engine.

**Wire-format matrix** (PR 7). ``uplink="sign"`` rides the same
exchange as ``"int8"`` with 1-bit payloads (blockwise mean-magnitude
scales, no SR draws — deterministic). Since PR 8 the sign payload is
bit-packed for the exchange by default (``UplinkConfig.sign_pack``):
the (P, 2, len) int8 rows become (P, 2, len/32) uint32 sign-plane
words before the ``all_to_all`` — a true 1 bit/coord wire under
zero-folding ("fold"), 2 bits/coord with the exact {-1, 0, +1}
bitplane pair ("planes"), or the PR 7 int8 container ("int8") — and
each device's receive launches unpack their own slice. ``UplinkConfig.error_feedback`` carries one FULL-WIDTH
residual row per transmitter (``SlabTrainState.ef``, sharded
``P(axes)`` on dim 0, scanned as carry by the runner): each device's
residual joins its noisy faded partial before the quantizer and the
fresh residual is written by the same fused launch. The clean
diagnostic payload gets no EF (it is a metric, not a transmission).
``OTAChannelConfig.downlink="int8"`` quantizes the model broadcast of
step 1: each device quantizes its OWN master slice before the
``all_gather`` (blocks are lane-aligned, so slice-local quantization
equals quantizing the full slab and slicing — the gathered broadcast
is bitwise the single-device reconstruction; the wire moves ~4x fewer
broadcast bytes), with the SR draw sliced from the one full-width
``DL_FOLD`` draw. The resident master slices stay f32 everywhere.

``shard_round_step`` keeps the PR-2 pytree-in/pytree-out signature for
drop-in use by ``make_round_step(backend="pallas_sharded")``: it packs
at the call boundary, runs the resident body once, and materialises
pytrees on the way out (an ``all_gather`` per call — inherent to a
pytree-per-round API; the masked-psum regather is gone from the
codebase). Multi-round loops should hold a ``SlabTrainState`` and use
the step/runner instead.
"""

from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.adaptive import AdaptiveConfig, slab_update_slabs
from repro.core.channel import (OTAChannelConfig, cms_transform,
                                cms_transform_fast, sample_fading,
                                sr_kernel_seed)
from repro.core.fl import FLConfig, RoundMetrics, _client_update
from repro.core.ota import (_cms_slab_inputs, _interference_slab_inputs,
                            downlink_quantize_slab, downlink_sr_slab_inputs,
                            linear_shard_index, restore_zero_tail,
                            uplink_sr_slab_inputs)
from repro.core.slab import SlabSpec, make_slab_spec, slab_to_tree, \
    stack_to_slab, tree_to_slab
from repro.core.slab_state import (SlabTrainState, pack_train_state,
                                   unpack_train_state)
from repro.core.stream import round_participation
from repro.core.tail_index import (effective_alpha, log_moment_stats,
                                   update_alpha_ema)
from repro.kernels.interpret import resolve_interpret
from repro.kernels.ota_channel import (LANE, ota_receive_slab,
                                       ota_transmit_slab, pack_sign_slab)

PyTree = Any


def client_axes_of(mesh) -> Tuple[str, ...]:
    """The client-carrying axes of a mesh: every axis except "model"."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_client_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in client_axes_of(mesh))


def psum_scatter_slab(x: jax.Array, axes: Tuple[str, ...],
                      dim: int = 0) -> jax.Array:
    """Reduce-scatter over possibly-several mesh axes, row-major.

    Scattering axis by axis in ``axes`` order splits dimension ``dim``
    into P = prod(axes sizes) blocks whose linear order matches
    ``linear_shard_index(axes)`` (first axis major) — the same layout a
    ``PartitionSpec(axes)`` on that dimension produces. Each device ends
    with the fully-summed block at its own linear index: the MAC
    superposition and the slice hand-off in one collective, moving about
    half the ring traffic of a full ``psum``.
    """
    for a in axes:
        x = jax.lax.psum_scatter(x, a, scatter_dimension=dim, tiled=True)
    return x


def all_gather_slab(x: jax.Array, axes: Tuple[str, ...],
                    dim: int = 0) -> jax.Array:
    """Inverse of ``psum_scatter_slab``'s layout: concatenate the
    per-device blocks back to full width (gather minor axis first)."""
    for a in reversed(axes):
        x = jax.lax.all_gather(x, a, axis=dim, tiled=True)
    return x


def exchange_uplink_payload(x: jax.Array, axes: Tuple[str, ...],
                            axis_sizes: Tuple[int, ...]) -> jax.Array:
    """The slice hand-off of the quantized MAC: a (possibly multi-axis)
    ``all_to_all`` on the leading per-destination dimension.

    ``x`` has shape (P, ...) where row p is this transmitter's payload
    block addressed to client-shard p (``linear_shard_index`` order,
    first axis major — the same layout ``psum_scatter_slab`` scatters).
    Returns (P, ...) where row q is the block received FROM shard q:
    the wire moves the quantized payload bytes, and the *superposition*
    happens after dequantization on the receiving device — a quantized
    MAC cannot sum int8 codewords with per-transmitter scales on the
    wire, so the reduce-scatter decomposes into all-to-all + local
    dequantized reduction (the receive kernel).

    Chaining per-axis ``all_to_all`` calls on a (A, B, ..., rest) view
    (axis i split and re-concatenated at position i) routes row
    (a, b, ...) to mesh coordinate (a, b, ...), matching the row-major
    linear shard index exactly.
    """
    rest = x.shape[1:]
    x = x.reshape(tuple(axis_sizes) + rest)
    for i, a in enumerate(axes):
        x = jax.lax.all_to_all(x, a, split_axis=i, concat_axis=i)
    return x.reshape((-1,) + rest)


def _use_inkernel_sr(channel_cfg: OTAChannelConfig,
                     stochastic: bool) -> bool:
    """Whether this launch draws its rounding bits in-kernel: the
    config opts in AND the launch is a compiled pallas one (interpret
    mode keeps the host-drawn oracle — the pltpu PRNG only lowers on
    TPU)."""
    return (stochastic and channel_cfg.uplink.sr_inkernel
            and not resolve_interpret(channel_cfg.interpret))


def _int8_uplink(channel_cfg: OTAChannelConfig, g_stack: jax.Array,
                 h_loc: jax.Array, key: jax.Array, kx: jax.Array,
                 idx: jax.Array, spec: SlabSpec, axes: Tuple[str, ...],
                 axis_sizes: Tuple[int, ...], n_total: int,
                 pilot_stats: bool = False, ef=None):
    """The quantized MAC, per device (call inside ``shard_map``).

    Stages quantize -> superposition -> interference -> dequantize of
    the uplink pipeline at ``uplink="int8"`` / ``"sign"``:

    1. ONE fused transmit launch per payload quantizes this device's
       faded partial sum (and the clean diagnostic sum — it rides the
       same wire, so the grad-norm metric reflects the quantized
       channel). ``"int8"``: per-128-block max/127 scales + stochastic
       rounding drawn from the round key (shard index folded in — the
       draws are per-transmitter, like the fading). ``"sign"``: 1-bit
       sign payloads with blockwise mean-magnitude scales,
       deterministic (no SR draws consumed). ``ef`` is this
       transmitter's carried (padded,) error-feedback residual: it
       joins the NOISY faded partial before its quantizer (the clean
       diagnostic payload gets no EF — it is a metric, not a
       transmission) and the fresh residual is returned.
    2. ``exchange_uplink_payload`` hands each device the P payload
       blocks addressed to its slab slice — the wire carries 1-byte
       codewords (1-BIT at ``"sign"``) + d/128 scales instead of
       4-byte floats.
    3. ONE fused receive launch per payload dequantizes + superposes
       the P rows and injects the CMS interference (clean payload:
       scale 0) on the slice only.

    Returns ``(g_slice, clean_slice, stats, ef_new)``, the slices
    (spec.shard_len,) f32, ``stats`` this device's (3,) residual
    log-moment epilogue reduction over ITS slice (None unless
    ``pilot_stats``; the caller psums the 3-vectors — stats are
    subset-agnostic by the zero-mask contract) and ``ef_new`` the fresh
    full-width (padded,) residual (None unless ``ef`` was passed).
    """
    qmode = channel_cfg.uplink.mode
    zero_fold = channel_cfg.uplink.zero_fold
    stochastic = channel_cfg.uplink.stochastic_rounding and qmode == "int8"
    inkernel = _use_inkernel_sr(channel_cfg, stochastic)
    if stochastic and not inkernel:
        r2 = uplink_sr_slab_inputs(key, spec, shard_index=idx)
        r_noisy, r_clean = r2[0], r2[1]
    else:
        r_noisy = r_clean = None
    if inkernel:
        seeds = sr_kernel_seed(key, shard_index=idx)
        seed_noisy, seed_clean = seeds[0], seeds[1]
    else:
        seed_noisy = seed_clean = None

    want_ef = ef is not None
    tx = ota_transmit_slab(
        g_stack, h_loc, n_total=n_total, quantize=True, r=r_noisy,
        stochastic=stochastic, qmode=qmode, zero_fold=zero_fold,
        sr_seed=seed_noisy, ef=ef,
        return_residual=want_ef, interpret=channel_cfg.interpret)
    q_noisy, s_noisy = tx[0], tx[1]
    ef_new = tx[2] if want_ef else None
    ones = jnp.ones((g_stack.shape[0],), jnp.float32)
    q_clean, s_clean = ota_transmit_slab(
        g_stack, ones, n_total=1, quantize=True, r=r_clean,
        stochastic=stochastic, qmode=qmode, zero_fold=zero_fold,
        sr_seed=seed_clean,
        interpret=channel_cfg.interpret)
    g_slice, clean_slice, stats = _exchange_and_receive(
        channel_cfg, q_noisy, s_noisy, q_clean, s_clean, kx, idx, spec,
        axes, axis_sizes, pilot_stats=pilot_stats)
    if channel_cfg.uplink.zero_fold and ef_new is not None:
        ef_new = restore_zero_tail(ef_new, spec)
    return g_slice, clean_slice, stats, ef_new


def _exchange_and_receive(channel_cfg: OTAChannelConfig, q_noisy, s_noisy,
                          q_clean, s_clean, kx: jax.Array, idx: jax.Array,
                          spec: SlabSpec, axes: Tuple[str, ...],
                          axis_sizes: Tuple[int, ...],
                          pilot_stats: bool = False):
    """Steps 2-3 of the quantized MAC: exchange this transmitter's two
    quantized payloads (noisy faded + clean diagnostic) over the wire
    and run the fused receive launches on this device's slice. Shared by
    the resident and the streamed uplink (which differ only in HOW the
    partial sums were formed before quantization).

    With a packed sign wire (``UplinkConfig.packed_sign``) the payload
    rows are bit-packed into uint32 words BEFORE the ``all_to_all`` —
    the collective moves 1 bit/coord (zero-folded) or 2 bits/coord
    (planes) instead of the 8-bit int8 container — and the receive
    launches unpack their own slice."""
    n_shards = math.prod(axis_sizes)
    shard_len = spec.shard_len
    sl = lambda s: jax.lax.dynamic_slice_in_dim(s, idx * shard_len,
                                                shard_len)

    # Rows addressed per destination slice, exchanged over the wire.
    payload = jnp.stack([q_noisy, q_clean]).reshape(
        2, n_shards, shard_len).transpose(1, 0, 2)        # (P, 2, len)
    scales = jnp.stack([s_noisy, s_clean]).reshape(
        2, n_shards, shard_len // LANE).transpose(1, 0, 2)
    packed = channel_cfg.uplink.packed_sign
    if packed:
        payload = pack_sign_slab(payload, planes=(packed == "planes"))
    comm_buckets = channel_cfg.comm_buckets
    payload = _bucketed_exchange(payload, comm_buckets, axes, axis_sizes)
    scales = _bucketed_exchange(scales, comm_buckets, axes, axis_sizes)

    # Full-width draws (or the disabled channel's (0, 1, 0.0) fixed
    # point), sliced — same helper as the single-device engines.
    u, e, xi_scale = _interference_slab_inputs(kx, channel_cfg, spec)
    u, e = sl(u), sl(e)
    stats = None
    g_slice = ota_receive_slab(
        payload[:, 0], scales[:, 0], u, e, alpha=channel_cfg.alpha,
        scale=xi_scale, packed=packed, pilot_stats=pilot_stats,
        interpret=channel_cfg.interpret)
    if pilot_stats:
        g_slice, stats = g_slice
    clean_slice = ota_receive_slab(
        payload[:, 1], scales[:, 1], jnp.zeros_like(u), jnp.ones_like(e),
        alpha=channel_cfg.alpha, scale=0.0, packed=packed,
        interpret=channel_cfg.interpret)
    if channel_cfg.uplink.zero_fold:
        # The fold wire dequantizes padding coords to +scale; the slab
        # layer owns the zero-tail contract, so this shard re-masks its
        # own columns (see ota.restore_zero_tail — fold-only, every
        # other wire's graph stays bitwise-untouched).
        off = idx * shard_len
        g_slice = restore_zero_tail(g_slice, spec, offset=off,
                                    width=shard_len)
        clean_slice = restore_zero_tail(clean_slice, spec, offset=off,
                                        width=shard_len)
    return g_slice, clean_slice, stats


def _bucketed_psum_scatter(rows: jax.Array, comm_buckets: int,
                           axes: Tuple[str, ...],
                           axis_sizes: Tuple[int, ...]) -> jax.Array:
    """Bucketed reduce-scatter of full-width rows: the MAC collective
    of the overlap engine.

    Device p owns contiguous columns [p*shard_len, (p+1)*shard_len) of
    each row, so bucket b must take the (P, B, sub) SUB-BLOCK view —
    columns [b*sub, (b+1)*sub) within every device block, not a flat
    split — and each of the B scatters moves a (R, P*sub) block whose
    result is this device's b-th sub-slice; concatenating the B results
    reassembles the slice exactly. Issued bucket by bucket so on
    backends with async collectives bucket b's ring transfer is in
    flight while bucket b+1's epilogue math runs. ``comm_buckets=1`` is
    the single ``psum_scatter_slab`` call, graph-identical to the
    default engine.
    """
    n_shards = math.prod(axis_sizes)
    if comm_buckets == 1:
        return psum_scatter_slab(rows, axes, dim=1)
    nrows = rows.shape[0]
    sub = rows.shape[1] // (n_shards * comm_buckets)
    blocks = rows.reshape(nrows, n_shards, comm_buckets, sub)
    outs = [psum_scatter_slab(
        blocks[:, :, b, :].reshape(nrows, n_shards * sub), axes, dim=1)
        for b in range(comm_buckets)]
    return jnp.concatenate(outs, axis=1)


def _bucketed_mac_f32(g_stack: jax.Array, coeff: jax.Array,
                      comm_buckets: int, axes: Tuple[str, ...],
                      axis_sizes: Tuple[int, ...]):
    """Resident-branch f32 MAC of the overlap engine: per bucket, the
    faded partial and the clean diagnostic sum fold as ONE
    (2, n_local) @ (n_local, cols) GEMM over that bucket's columns
    (``coeff`` rows: ``h*(1/n)`` and the all-ones diagnostic), and its
    reduce-scatter is issued before the next bucket's fold — transmit
    epilogue b+1 overlaps collective b. The GEMM reassociates the
    transmit kernel's per-row accumulation (tolerance parity tier, like
    ``repro.core.stream``'s fold); ``comm_buckets=1`` callers keep the
    kernel path instead. Returns ``(g_slice, clean_slice)``."""
    n_shards = math.prod(axis_sizes)
    n_loc = g_stack.shape[0]
    sub = g_stack.shape[1] // (n_shards * comm_buckets)
    blocks = g_stack.reshape(n_loc, n_shards, comm_buckets, sub)
    outs = [psum_scatter_slab(
        coeff @ blocks[:, :, b, :].reshape(n_loc, n_shards * sub),
        axes, dim=1) for b in range(comm_buckets)]
    both = jnp.concatenate(outs, axis=1)
    return both[0], both[1]


def _bucketed_exchange(x: jax.Array, comm_buckets: int,
                       axes: Tuple[str, ...],
                       axis_sizes: Tuple[int, ...]) -> jax.Array:
    """Bucketed ``exchange_uplink_payload``: split the per-destination
    payload columns into B buckets and exchange bucket by bucket, so
    bucket b's ``all_to_all`` overlaps bucket b+1's staging. The result
    is VALUE-identical to the single exchange (a column split of every
    (source, dest) block, re-concatenated in order); ``comm_buckets=1``
    is the plain call."""
    if comm_buckets == 1:
        return exchange_uplink_payload(x, axes, axis_sizes)
    sub = x.shape[-1] // comm_buckets
    outs = [exchange_uplink_payload(
        x[..., b * sub:(b + 1) * sub], axes, axis_sizes)
        for b in range(comm_buckets)]
    return jnp.concatenate(outs, axis=-1)


def _overlap_interference(channel_cfg: OTAChannelConfig, kx: jax.Array,
                          sl, spec: SlabSpec, g_slice: jax.Array,
                          track: bool):
    """Interference injection for the overlap engine's f32 branches:
    the SAME full-width per-leaf draws as the default engine (the PRNG
    contract never changes with ``comm_buckets``), but the slice goes
    through :func:`cms_transform_fast` — the single-exp reformulation,
    ~2x cheaper and a few float32 ulps off the pinned form, which is
    what puts the whole ``comm_buckets > 1`` engine on the tolerance
    parity tier. Returns ``(g_slice, stats)``."""
    if not channel_cfg.interference:
        return g_slice, None
    u, e = _cms_slab_inputs(kx, spec)
    xi_slice = channel_cfg.xi_scale * cms_transform_fast(
        sl(u), sl(e), channel_cfg.alpha)
    g_slice = g_slice + xi_slice
    stats = log_moment_stats(xi_slice) if track else None
    return g_slice, stats


def _make_bcast_fn(channel_cfg: OTAChannelConfig, spec: SlabSpec,
                   axes: Tuple[str, ...]):
    """The model-broadcast leg as a reusable closure: quantize this
    device's slice (int8 downlink only; the SR draw is the one
    full-width downlink draw off ``key``, sliced at the shard offset)
    and all-gather to full width. Shared by the in-round broadcast and
    the overlap engine's PREFETCHED broadcast (round t issues round
    t+1's gather with round t+1's key, so the collective is in flight
    across the round boundary)."""
    dl_int8 = channel_cfg.downlink == "int8"
    shard_len = spec.shard_len

    def bcast(w_slice, key):
        if dl_int8:
            idx = linear_shard_index(axes)
            r_dl = jax.lax.dynamic_slice_in_dim(
                downlink_sr_slab_inputs(key, spec.padded),
                idx * shard_len, shard_len)
            b_slice = downlink_quantize_slab(w_slice, r_dl)
        else:
            b_slice = w_slice
        return all_gather_slab(b_slice, axes)

    return bcast


def _make_round_body(loss_fn, channel_cfg: OTAChannelConfig,
                     adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig,
                     axes: Tuple[str, ...], axis_sizes: Tuple[int, ...],
                     spec: SlabSpec, prefetch_bcast: bool = False):
    """Per-device resident round: slices in, slices out (call inside
    ``shard_map``). One transmit and one ``adaptive_update_slab``
    launch per device, one ``all_gather`` (the model broadcast) and one
    MAC collective per round — ``psum_scatter`` of the f32 partial sums
    at ``uplink="f32"``, an ``all_to_all`` of int8 payloads + per-block
    f32 scales (~4x fewer wire bytes) at ``uplink="int8"``.

    ``channel_cfg.comm_buckets > 1`` selects the OVERLAP engine: the
    MAC collective splits into B bucketed collectives interleaved with
    the per-bucket transmit epilogue, the f32 branches fold the partial
    sums as per-bucket GEMMs with the fast-exp CMS transform, and the
    per-round scalar reductions (loss, both norms, pilot stats) fuse
    into one stacked psum — the tolerance parity tier. ``comm_buckets
    == 1`` keeps the default engine's graph bitwise-untouched.

    ``prefetch_bcast`` (overlap runner only): the body takes two extra
    trailing operands ``(next_key, w_bcast)`` — the CURRENT round's
    already-gathered broadcast — skips its own gather, and returns the
    NEXT round's broadcast as an extra output, issued with ``next_key``
    at the end of this round's program so the gather is in flight
    across the scan's round boundary."""
    n = fl_cfg.n_clients
    comm_buckets = channel_cfg.comm_buckets
    overlap = comm_buckets > 1
    if overlap:
        if (spec.shard_len // LANE) % comm_buckets != 0:
            raise ValueError(
                f"comm_buckets={comm_buckets} must divide the per-shard "
                f"{LANE}-block count {spec.shard_len // LANE} "
                f"(shard_len={spec.shard_len}); pick a power-of-two "
                f"bucket count or a smaller one")
    if prefetch_bcast and not overlap:
        raise ValueError("prefetch_bcast is the overlap engine's round "
                         "shape; it needs comm_buckets > 1")
    n_shards = math.prod(axis_sizes)
    n_local = n // n_shards
    shard_len = spec.shard_len
    client_fn = _client_update(loss_fn, fl_cfg)
    has_cast = any(dt != jnp.float32 for dt in spec.dtypes)
    uplink = channel_cfg.uplink
    use_ef = uplink.error_feedback
    dl_int8 = channel_cfg.downlink == "int8"
    track = adaptive_cfg.track_alpha
    dynamic = fl_cfg.dynamic_round
    dynamic_norm = fl_cfg.dynamic_norm
    # client_chunk bounds the RESIDENT client rows per device: the local
    # population streams through the accumulating transmit kernel in
    # chunks of this many rows (the client axis is already divided by
    # the mesh, so the chunk applies to each device's n_local share; a
    # chunk that does not divide n_local gets a ragged final chunk —
    # zero-gain padding rows, same contract as repro.core.stream).
    chunk = min(fl_cfg.client_chunk or n_local, n_local)
    n_chunks_loc = -(-n_local // chunk)
    n_local_pad = n_chunks_loc * chunk
    ragged = n_local_pad != n_local
    bcast_fn = _make_bcast_fn(channel_cfg, spec, axes)

    def round_body(step, w_slice, opt_slices, alpha_hat, ef_rows, key,
                   local_batches, next_key=None, w_bcast=None):
        idx = linear_shard_index(axes)
        sl = lambda s: jax.lax.dynamic_slice_in_dim(s, idx * shard_len,
                                                    shard_len)
        w_orig, opt_orig, alpha_orig = w_slice, opt_slices, alpha_hat
        ef = ef_rows[0] if use_ef else None

        # --- 1. model broadcast: slices -> full slab -> pytree --------
        # Under downlink="int8" each device quantizes ITS slice before
        # the gather (blocks are lane-aligned and shard slices are
        # 128-multiples, so slice-local quantization equals quantizing
        # the full slab and slicing — the gathered broadcast is bitwise
        # the single-device reconstruction). The SR draw is the one
        # full-width downlink draw, sliced at the shard offset. The
        # resident master slice w_slice stays f32. Under the prefetched
        # round shape the broadcast already happened — at the END of
        # the previous round's program, with THIS round's key.
        if prefetch_bcast:
            w_full = w_bcast
        else:
            w_full = bcast_fn(w_slice, key)
        params = slab_to_tree(spec, w_full)

        kh, kx = jax.random.split(key)
        h = sample_fading(kh, channel_cfg, (n,))
        stats = None
        ef_new = None

        if not dynamic:
            # --- 2. local client compute + power control (in h) -------
            grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(
                params, local_batches)
            h_loc = jax.lax.dynamic_slice_in_dim(h, idx * n_local, n_local)
            g_stack = stack_to_slab(spec, grads)          # (n_local, padded)

            if uplink.quantized:
                g_slice, clean_slice, stats, ef_new = _int8_uplink(
                    channel_cfg, g_stack, h_loc, key, kx, idx, spec, axes,
                    axis_sizes, n, pilot_stats=track, ef=ef)
            elif overlap:
                # Overlap engine: both reductions (faded partial +
                # clean diagnostic) fold as one GEMM per bucket,
                # interleaved with the bucketed reduce-scatter; the
                # interference slice takes the fast-exp CMS transform.
                coeff = jnp.stack([h_loc * (1.0 / n),
                                   jnp.ones_like(h_loc)])
                g_slice, clean_slice = _bucketed_mac_f32(
                    g_stack, coeff, comm_buckets, axes, axis_sizes)
                g_slice, stats = _overlap_interference(
                    channel_cfg, kx, sl, spec, g_slice, track)
            else:
                # Fused transmit: the faded partial sum over the local
                # client rows, full slab width, analog (f32) wire format.
                partial = ota_transmit_slab(g_stack, h_loc, n_total=n,
                                            interpret=channel_cfg.interpret)
                clean_part = jnp.sum(g_stack, axis=0)

                # The superposition: reduce-scatter == MAC + slice
                # hand-off.
                both = psum_scatter_slab(jnp.stack([partial, clean_part]),
                                         axes, dim=1)     # (2, shard_len)
                g_slice, clean_slice = both[0], both[1]

                # Interference, synthesized on this slice only:
                # full-width per-leaf draws (identical to the
                # single-device backends — PRNG is compute, not comms),
                # CMS transform on the slice; added once, post-reduce —
                # the server's single RF front end.
                if channel_cfg.interference:
                    u, e = _cms_slab_inputs(kx, spec)
                    xi_slice = channel_cfg.xi_scale * cms_transform(
                        sl(u), sl(e), channel_cfg.alpha)
                    g_slice = g_slice + xi_slice
                    if track:
                        # The pilot-stats reduction over this slice's
                        # residual (the jnp mirror of the kernel
                        # epilogue — the f32 sharded interference is
                        # injected in jnp).
                        stats = log_moment_stats(xi_slice)
            if overlap:
                # Deferred: the loss term rides the fused metrics psum.
                loss_in = jnp.mean(losses)
                loss_div = jnp.asarray(float(n_shards), jnp.float32)
            else:
                loss_metric = jax.lax.pmean(jnp.mean(losses), axes)
            norm = den = jnp.asarray(float(n), jnp.float32)
            n_part = jnp.asarray(float(n), jnp.float32)
        else:
            # --- 2'. STREAMED local client axis (repro.core.stream
            # contract): participation mask and weights are full-width
            # draws off the round key — identical on every device, no
            # collective — folded into the effective fading; the local
            # rows stream through the accumulating transmit kernel in
            # O(chunk * d) memory.
            mask, gain = round_participation(key, fl_cfg)
            h_eff = h * gain if dynamic_norm else h
            n_div = 1 if dynamic_norm else n
            n_part = jnp.sum(mask)
            norm = jnp.sum(gain) if dynamic_norm else n_part
            norm_safe = jnp.where(norm > 0.0, norm, 1.0)
            h_loc = jax.lax.dynamic_slice_in_dim(h_eff, idx * n_local,
                                                 n_local)
            m_loc = jax.lax.dynamic_slice_in_dim(mask, idx * n_local,
                                                 n_local)
            if ragged:
                # Ragged final chunk: zero-gain padding rows past the
                # local population (their batch rows re-read local row
                # n_local-1, multiplied by the zero gain/mask — exactly
                # 0.0 folded in; repro.core.stream's contract).
                h_loc = jnp.pad(h_loc, (0, n_local_pad - n_local))
                m_loc = jnp.pad(m_loc, (0, n_local_pad - n_local))

            def produce_loc(c):
                """Chunk c's local client compute + operand slices (the
                double-buffer SLOT; see repro.core.stream.produce)."""
                start = c * chunk
                if ragged:
                    cidx = jnp.minimum(start + jnp.arange(chunk),
                                       n_local - 1)
                    batch = jax.tree.map(lambda b: jnp.take(b, cidx, axis=0),
                                         local_batches)
                else:
                    batch = jax.tree.map(
                        lambda b: jax.lax.dynamic_slice_in_dim(b, start,
                                                               chunk),
                        local_batches)
                grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(
                    params, batch)
                g_stack = stack_to_slab(spec, grads)
                h_c = jax.lax.dynamic_slice_in_dim(h_loc, start, chunk)
                m_c = jax.lax.dynamic_slice_in_dim(m_loc, start, chunk)
                return g_stack, h_c, m_c, losses

            def chunk_body(carry, c):
                acc, clean, loss_sum = carry
                g_stack, h_c, m_c, losses = produce_loc(c)
                acc = ota_transmit_slab(g_stack, h_c, n_total=n_div,
                                        acc=acc,
                                        interpret=channel_cfg.interpret)
                clean = clean + jnp.sum(m_c[:, None] * g_stack, axis=0)
                loss_sum = loss_sum + jnp.sum(m_c * losses)
                return (acc, clean, loss_sum), None

            def fold_loc(carry, slot):
                # Fused dual reduction of a completed slot (the
                # double-buffered fold — same tolerance-tier
                # reassociation as repro.core.stream.fold).
                acc, clean, loss_sum = carry
                g_stack, h_c, m_c, losses = slot
                coeff = jnp.stack([h_c * (1.0 / n_div), m_c])
                both = coeff @ g_stack
                return (acc + both[0], clean + both[1],
                        loss_sum + jnp.sum(m_c * losses))

            def db_chunk_body(carry, c):
                acc, clean, loss_sum, slot = carry
                new_slot = produce_loc(c)
                acc, clean, loss_sum = fold_loc((acc, clean, loss_sum),
                                                slot)
                return (acc, clean, loss_sum, new_slot), None

            zeros = jnp.zeros((spec.padded,), jnp.float32)
            carry = (zeros, zeros, jnp.zeros((), jnp.float32))
            if chunk == n_local:
                carry, _ = chunk_body(carry, jnp.zeros((), jnp.int32))
            elif fl_cfg.double_buffer:
                carry = (*carry, produce_loc(0))
                carry, _ = jax.lax.scan(
                    db_chunk_body, carry,
                    jnp.arange(1, n_chunks_loc, dtype=jnp.int32))
                carry = fold_loc(carry[:3], carry[3])
            else:
                carry, _ = jax.lax.scan(
                    chunk_body, carry,
                    jnp.arange(n_chunks_loc, dtype=jnp.int32))
            partial, clean_part, loss_sum = carry

            if uplink.quantized:
                # Pre-divide the noisy partial by the (globally known)
                # participation norm before quantization, so the
                # dequantized superposition lands already normalised;
                # the clean diagnostic partial stays raw (the metric
                # divides by the participant count).
                noisy_part = partial / norm_safe if dynamic_norm else partial
                qmode = uplink.mode
                zero_fold = uplink.zero_fold
                stochastic = (uplink.stochastic_rounding
                              and qmode == "int8")
                inkernel = _use_inkernel_sr(channel_cfg, stochastic)
                if stochastic and not inkernel:
                    r2 = uplink_sr_slab_inputs(key, spec, shard_index=idx)
                    r_noisy, r_clean = r2[0], r2[1]
                else:
                    r_noisy = r_clean = None
                if inkernel:
                    seeds = sr_kernel_seed(key, shard_index=idx)
                    seed_noisy, seed_clean = seeds[0], seeds[1]
                else:
                    seed_noisy = seed_clean = None
                one = jnp.ones((1,), jnp.float32)
                tx = ota_transmit_slab(
                    noisy_part[None], one, n_total=1, quantize=True,
                    r=r_noisy, stochastic=stochastic, qmode=qmode,
                    zero_fold=zero_fold, sr_seed=seed_noisy,
                    ef=ef, return_residual=use_ef,
                    interpret=channel_cfg.interpret)
                q_noisy, s_noisy = tx[0], tx[1]
                if use_ef:
                    ef_new = tx[2]
                q_clean, s_clean = ota_transmit_slab(
                    clean_part[None], one, n_total=1, quantize=True,
                    r=r_clean, stochastic=stochastic, qmode=qmode,
                    zero_fold=zero_fold, sr_seed=seed_clean,
                    interpret=channel_cfg.interpret)
                g_slice, clean_slice, stats = _exchange_and_receive(
                    channel_cfg, q_noisy, s_noisy, q_clean, s_clean, kx,
                    idx, spec, axes, axis_sizes, pilot_stats=track)
                if channel_cfg.uplink.zero_fold and use_ef:
                    ef_new = restore_zero_tail(ef_new, spec)
            elif overlap:
                both = _bucketed_psum_scatter(
                    jnp.stack([partial, clean_part]), comm_buckets, axes,
                    axis_sizes)
                g_slice, clean_slice = both[0], both[1]
                if dynamic_norm:
                    g_slice = g_slice / norm_safe
                g_slice, stats = _overlap_interference(
                    channel_cfg, kx, sl, spec, g_slice, track)
            else:
                both = psum_scatter_slab(jnp.stack([partial, clean_part]),
                                         axes, dim=1)
                g_slice, clean_slice = both[0], both[1]
                if dynamic_norm:
                    g_slice = g_slice / norm_safe
                if channel_cfg.interference:
                    u, e = _cms_slab_inputs(kx, spec)
                    xi_slice = channel_cfg.xi_scale * cms_transform(
                        sl(u), sl(e), channel_cfg.alpha)
                    g_slice = g_slice + xi_slice
                    if track:
                        stats = log_moment_stats(xi_slice)
            den = jnp.maximum(n_part, 1.0)
            if overlap:
                loss_in = loss_sum
                loss_div = den
            else:
                loss_metric = jax.lax.psum(loss_sum, axes) / den

        # --- alpha loop: psum the per-slice stats, fold into the EMA --
        if overlap:
            # Fused cross-device reduction: the loss term, both norm
            # squared-sums and (when tracked) the 3 pilot moments ride
            # ONE stacked psum instead of 3-4 scalar collectives —
            # fewer rendezvous on the round's critical path. Elementwise
            # the sums are the same reductions the default engine runs.
            parts = [loss_in[None],
                     jnp.sum(jnp.square(clean_slice))[None],
                     jnp.sum(jnp.square(g_slice))[None]]
            if track:
                parts.append(stats if stats is not None
                             else jnp.zeros((3,), jnp.float32))
            red = jax.lax.psum(jnp.concatenate(parts), axes)
            loss_metric = red[0] / loss_div
            grad_norm_metric = jnp.sqrt(red[1])
            noisy_norm_metric = jnp.sqrt(red[2])
            if track:
                stats = red[3:6]
        if track:
            if not overlap:
                if stats is None:    # interference disabled: no residual
                    stats = jnp.zeros((3,), jnp.float32)
                stats = jax.lax.psum(stats, axes)
            alpha_hat = update_alpha_ema(alpha_hat, stats,
                                         adaptive_cfg.alpha_ema)
            alpha_arg = effective_alpha(alpha_hat)
            alpha_metric = alpha_hat
        else:
            alpha_arg = None
            alpha_metric = jnp.asarray(adaptive_cfg.alpha, jnp.float32)

        # --- 5. fused server update on the RESIDENT slices ------------
        if has_cast:
            # Non-f32 leaves round-trip through their storage dtype each
            # round on every other backend; mirror that here for parity.
            # The cast applies to the MASTER weights: under the int8
            # downlink ``params`` is the quantized broadcast, so the
            # master slices are regathered for the round trip (rare
            # config — non-f32 leaves + quantized downlink).
            src = (params if not dl_int8
                   else slab_to_tree(spec, all_gather_slab(w_orig, axes)))
            w_slice = sl(tree_to_slab(spec, src))
        new_opt, w_new = slab_update_slabs(adaptive_cfg, g_slice, opt_slices,
                                           w_slice, alpha=alpha_arg)
        ef_out = ef_new[None] if use_ef else ef_rows
        if dynamic_norm:
            # Zero-participation skip: nobody transmitted, so the state
            # carries over unchanged (only the round counter advances).
            participated = norm > 0.0
            w_new = jnp.where(participated, w_new, w_orig)
            new_opt = tuple(jnp.where(participated, o_n, o_o)
                            for o_n, o_o in zip(new_opt, opt_orig))
            if use_ef:
                # No transmission happened: the carried residual is NOT
                # replaced by the residual of a phantom transmit.
                ef_out = jnp.where(participated, ef_out, ef_rows)
            if track:
                alpha_hat = jnp.where(participated, alpha_hat, alpha_orig)
                alpha_metric = alpha_hat

        # Norms from per-slice squared sums: no full-width regather.
        if not overlap:
            grad_norm_metric = jnp.sqrt(jax.lax.psum(
                jnp.sum(jnp.square(clean_slice)), axes))
            noisy_norm_metric = jnp.sqrt(jax.lax.psum(
                jnp.sum(jnp.square(g_slice)), axes))
        metrics = RoundMetrics(
            loss=loss_metric,
            grad_norm=grad_norm_metric / den,
            noisy_grad_norm=noisy_norm_metric,
            fading_mean=jnp.mean(h),
            alpha_hat=alpha_metric,
            n_participants=n_part,
        )
        if prefetch_bcast:
            # Issue the NEXT round's broadcast before handing the carry
            # back to the scan: its gather is in flight while the scan
            # crosses the round boundary into round t+1's client
            # compute. The draw key is round t+1's — the int8 downlink
            # reconstruction must be bitwise what an in-round broadcast
            # would produce.
            return (step + 1, w_new, new_opt, alpha_hat, ef_out, metrics,
                    bcast_fn(w_new, next_key))
        return step + 1, w_new, new_opt, alpha_hat, ef_out, metrics

    return round_body


def _validate_mesh(fl_cfg: FLConfig, mesh
                   ) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    axes = client_axes_of(mesh)
    if not axes:
        raise ValueError("mesh has no client-carrying axes (all axes are "
                         "'model'); the sharded slab engine needs at least "
                         "one")
    n_shards = n_client_shards(mesh)
    n = fl_cfg.n_clients
    if n % n_shards != 0:
        raise ValueError(
            f"n_clients={n} must be divisible by the mesh's client-shard "
            f"count {n_shards} (axes {axes} of mesh shape {dict(mesh.shape)})")
    return axes, tuple(mesh.shape[a] for a in axes)


def _check_spec_shards(spec: SlabSpec, n_shards: int) -> None:
    if spec.shards != n_shards:
        raise ValueError(
            f"SlabTrainState was laid out for shards={spec.shards} but the "
            f"mesh has {n_shards} client shards; build the state with "
            f"init_train_state(..., shards={n_shards})")


def _check_ef_rows(state: SlabTrainState, use_ef: bool,
                   n_shards: int) -> None:
    if use_ef and state.ef is None:
        raise ValueError(
            "UplinkConfig.error_feedback=True but the SlabTrainState "
            "carries no residual rows; build it with "
            f"init_train_state(..., shards={n_shards}, "
            "error_feedback=True)")
    if use_ef and state.ef.shape[0] != n_shards:
        raise ValueError(
            f"SlabTrainState.ef has {state.ef.shape[0]} transmitter rows "
            f"but the mesh has {n_shards} client shards")


def make_shard_slab_step(loss_fn, channel_cfg: OTAChannelConfig,
                         adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig,
                         mesh, jit: bool = True):
    """One resident round over ``mesh``: ``step(state, key, client_batches)
    -> (state, metrics)`` where ``state`` is a ``SlabTrainState`` whose
    slabs live sharded over the mesh's client axes (``P(axes)`` on dim 0
    — globally they keep their full (padded,) shapes, so checkpoints and
    boundary conversions are mesh-agnostic).

    ``client_batches`` leaves carry the global client axis N up front.
    No full-model regather happens: the round ends with the updated
    slices in place.
    """
    axes, axis_sizes = _validate_mesh(fl_cfg, mesh)
    n_shards = math.prod(axis_sizes)
    use_ef = channel_cfg.uplink.error_feedback
    # The EF residual rows are sharded over the client axes on dim 0
    # (one (1, padded) row per transmitter, like its fading slice); when
    # EF is off a replicated scalar dummy keeps the shard_map signature
    # static and the state's ef stays None end to end.
    ef_spec = P(axes) if use_ef else P()

    def step(state: SlabTrainState, key, client_batches):
        _check_spec_shards(state.spec, n_shards)
        _check_ef_rows(state, use_ef, n_shards)
        body = _make_round_body(loss_fn, channel_cfg, adaptive_cfg, fl_cfg,
                                axes, axis_sizes, state.spec)
        sharded = shard_map(
            body, mesh,
            in_specs=(P(), P(axes), P(axes), P(), ef_spec, P(), P(axes)),
            out_specs=(P(), P(axes), P(axes), P(), ef_spec, P()))
        ef_in = state.ef if use_ef else jnp.zeros((), jnp.float32)
        new_step, w, opt, alpha_hat, ef_out, m = sharded(
            state.step, state.w, state.opt, state.alpha_hat, ef_in, key,
            client_batches)
        return SlabTrainState(new_step, w, tuple(opt), alpha_hat,
                              state.spec, ef_out if use_ef else state.ef), m

    return jax.jit(step) if jit else step


def make_shard_slab_runner(loss_fn, channel_cfg: OTAChannelConfig,
                           adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig,
                           mesh, jit: bool = True, donate: bool = False):
    """R resident rounds as ONE ``jax.lax.scan`` inside ``shard_map``:
    ``run(state, keys, client_batches) -> (state, metrics)`` with
    ``keys`` a (R,) key array and ``client_batches`` leaves shaped
    (R, N, ...). The scanned body is the same per-device resident round
    as ``make_shard_slab_step`` — state slices are the carry, so the
    whole R-round trajectory executes with zero full-model regathers and
    zero host round trips; metrics come back stacked (R,).

    ``donate=True`` donates the incoming ``SlabTrainState`` buffers
    (``donate_argnums=(0,)``): XLA aliases each slab (w, opt, alpha_hat,
    ef) to its output — the resident update is genuinely in place, no
    2x copy of the training state lives across the call. The caller's
    state object is CONSUMED (reusing it raises jax's donated-buffer
    error) — thread the returned state forward, as ``run_rounds_slab``
    and ``launch.train`` do. Requires ``jit``.
    """
    axes, axis_sizes = _validate_mesh(fl_cfg, mesh)
    n_shards = math.prod(axis_sizes)
    use_ef = channel_cfg.uplink.error_feedback
    ef_spec = P(axes) if use_ef else P()

    prefetch = channel_cfg.comm_buckets > 1

    def run(state: SlabTrainState, keys, client_batches):
        _check_spec_shards(state.spec, n_shards)
        _check_ef_rows(state, use_ef, n_shards)
        spec_ = state.spec
        body = _make_round_body(loss_fn, channel_cfg, adaptive_cfg, fl_cfg,
                                axes, axis_sizes, spec_,
                                prefetch_bcast=prefetch)

        def scan_rounds(step0, w_slice, opt_slices, alpha0, ef0, keys,
                        keys_next, batches):
            if prefetch:
                # Overlap engine: the broadcast moves to the END of the
                # previous round's program (issued with the next round's
                # key), so its all_gather is in flight across the scan's
                # round boundary; the prologue gathers round 0's
                # broadcast once, outside the scan.
                bcast = _make_bcast_fn(channel_cfg, spec_, axes)

                def scanned(carry, xs):
                    step, w, opt, alpha_hat, ef, wb = carry
                    key, nkey, batch = xs
                    step, w, opt, alpha_hat, ef, m, wb = body(
                        step, w, opt, alpha_hat, ef, key, batch, nkey, wb)
                    return (step, w, opt, alpha_hat, ef, wb), m

                wb0 = bcast(w_slice, keys[0])
                (step, w, opt, alpha_hat, ef, _), ms = jax.lax.scan(
                    scanned,
                    (step0, w_slice, opt_slices, alpha0, ef0, wb0),
                    (keys, keys_next, batches))
            else:
                def scanned(carry, xs):
                    step, w, opt, alpha_hat, ef = carry
                    key, batch = xs
                    step, w, opt, alpha_hat, ef, m = body(
                        step, w, opt, alpha_hat, ef, key, batch)
                    return (step, w, opt, alpha_hat, ef), m

                (step, w, opt, alpha_hat, ef), ms = jax.lax.scan(
                    scanned, (step0, w_slice, opt_slices, alpha0, ef0),
                    (keys, batches))
            return step, w, opt, alpha_hat, ef, ms

        sharded = shard_map(
            scan_rounds, mesh,
            in_specs=(P(), P(axes), P(axes), P(), ef_spec, P(), P(),
                      P(None, axes)),
            out_specs=(P(), P(axes), P(axes), P(), ef_spec, P()))
        ef_in = state.ef if use_ef else jnp.zeros((), jnp.float32)
        if prefetch:
            # Round t's body prefetches round t+1's broadcast with round
            # t+1's key; the final round's prefetch result is dropped,
            # so its (arbitrary) key only has to exist.
            keys_next = jnp.concatenate([keys[1:], keys[-1:]])
        else:
            keys_next = jnp.zeros((), jnp.float32)
        new_step, w, opt, alpha_hat, ef_out, ms = sharded(
            state.step, state.w, state.opt, state.alpha_hat, ef_in, keys,
            keys_next, client_batches)
        return SlabTrainState(new_step, w, tuple(opt), alpha_hat,
                              state.spec, ef_out if use_ef else state.ef
                              ), ms

    if donate and not jit:
        raise ValueError("donate=True needs jit=True: buffer donation "
                         "is a property of the compiled executable")
    if not jit:
        return run
    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


def shard_round_step(loss_fn, channel_cfg: OTAChannelConfig,
                     adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig, mesh,
                     jit: bool = True):
    """PR-2-compatible pytree API over the resident engine.

    ``round_step(params, opt_state, key, client_batches)`` with full
    (replicated) pytrees in and out — the signature
    ``make_round_step(backend="pallas_sharded")`` promises. Internally
    it packs to a ``SlabTrainState`` at the call boundary, runs the
    resident round once, and materialises pytrees on the way out. The
    per-call boundary conversion is inherent to a pytree-per-round API;
    multi-round training should keep the ``SlabTrainState`` resident via
    ``make_shard_slab_step``/``make_shard_slab_runner`` instead.
    """
    if adaptive_cfg.track_alpha:
        raise ValueError(
            'AdaptiveConfig.alpha == "auto" needs the resident loop '
            '(make_shard_slab_step / make_shard_slab_runner): the pytree-'
            'per-round wrapper re-packs the state every call, which would '
            'reset the estimator EMA each round')
    if channel_cfg.uplink.error_feedback:
        raise ValueError(
            "error_feedback needs the resident loop (make_shard_slab_step "
            "/ make_shard_slab_runner): the pytree-per-round wrapper "
            "re-packs the state every call, which would zero the carried "
            "residual each round")
    axes, axis_sizes = _validate_mesh(fl_cfg, mesh)
    n_shards = math.prod(axis_sizes)
    inner = make_shard_slab_step(loss_fn, channel_cfg, adaptive_cfg, fl_cfg,
                                 mesh, jit=False)

    def round_step(params, opt_state, key, client_batches):
        spec = make_slab_spec(params, shards=n_shards)
        state = pack_train_state(adaptive_cfg, spec, params, opt_state)
        state, metrics = inner(state, key, client_batches)
        new_params, new_state = unpack_train_state(adaptive_cfg, state)
        return new_params, new_state, metrics

    return jax.jit(round_step) if jit else round_step
