"""Qwen3-235B-A22B [hf:Qwen/Qwen3-30B-A3B card family]: 94L, d_model
4096, 64 heads (GQA kv=4, head_dim 128), qk-norm; MoE 128 experts top-8,
expert d_ff 1536, vocab 151936."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, n_experts=128, top_k=8, qk_norm=True,
    rope_theta=1000000.0,
    notes="128 experts top-8 [hf:Qwen/Qwen3-30B-A3B card family]",
)
