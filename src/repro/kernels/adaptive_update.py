"""Fused ADOTA server-update Pallas kernel.

The ADOTA update (Eq. 8-11) is elementwise over every parameter:

    Delta <- b1*Delta + (1-b1)*g
    v     <- v + |Delta|^a            (or EMA for Adam-OTA)
    w     <- w - lr * Delta / (v+eps)^{1/a}

Naively chained in jnp this is ~10 HBM round-trips over 4 model-sized
arrays; the fractional |.|^a and (.)^{1/a} powers (exp/log on the VPU)
make it strictly memory-bound. The kernel performs the whole update in
ONE read-modify-write pass per block: each grid step streams a
(block_rows, 128) tile of {g, Delta, v, w} HBM->VMEM, does all the math
in VMEM/VREGs, and writes the three outputs back.

TPU is the target (bf16/f32 tiles aligned to the 8x128 VPU lanes); on
this CPU container the kernel body is validated with interpret=True
against ``ref.adaptive_update_ref``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256     # (256, 128) f32 tile = 128 KiB per operand


def _adaptive_update_kernel(g_ref, delta_ref, nu_ref, w_ref,
                            delta_out, nu_out, w_out,
                            *, lr: float, beta1: float, beta2: float,
                            alpha: float, eps: float, adagrad: bool):
    g = g_ref[...].astype(jnp.float32)
    delta = beta1 * delta_ref[...] + (1.0 - beta1) * g
    da = jnp.exp(alpha * jnp.log(jnp.maximum(jnp.abs(delta), 1e-30)))
    da = jnp.where(delta == 0.0, 0.0, da)
    if adagrad:
        nu = nu_ref[...] + da
    else:
        nu = beta2 * nu_ref[...] + (1.0 - beta2) * da
    denom = jnp.exp(jnp.log(nu + eps) / alpha)
    w = w_ref[...].astype(jnp.float32) - lr * delta / denom
    delta_out[...] = delta
    nu_out[...] = nu
    w_out[...] = w.astype(w_out.dtype)


def adaptive_update_slab(g: jax.Array, delta: jax.Array, nu: jax.Array,
                         w: jax.Array, *, lr: float, beta1: float,
                         beta2: float, alpha: float, eps: float, mode: str,
                         block_rows: int = DEFAULT_BLOCK_ROWS,
                         interpret: bool = True
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused update on a 1-D parameter slab (any length; padded to lanes).

    g/w may be bf16 or f32; delta/nu are f32 state. Returns (delta', nu', w').
    """
    n = g.shape[0]
    rows = -(-n // LANE)
    rows_pad = -(-rows // block_rows) * block_rows
    total = rows_pad * LANE

    def shape2d(x, dt=None):
        x = jnp.pad(x, (0, total - n))
        return x.reshape(rows_pad, LANE).astype(dt or x.dtype)

    g2 = shape2d(g)
    d2 = shape2d(delta, jnp.float32)
    v2 = shape2d(nu, jnp.float32)
    w2 = shape2d(w)

    grid = (rows_pad // block_rows,)
    blk = lambda dt: pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    kernel = functools.partial(
        _adaptive_update_kernel, lr=lr, beta1=beta1, beta2=beta2,
        alpha=alpha, eps=eps, adagrad=(mode == "adagrad"))
    d_new, v_new, w_new = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk(None)] * 4,
        out_specs=[blk(None)] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((rows_pad, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows_pad, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows_pad, LANE), w.dtype),
        ],
        interpret=interpret,
    )(g2, d2, v2, w2)
    unpad = lambda x2, dt: x2.reshape(-1)[:n].astype(dt)
    return (unpad(d_new, jnp.float32), unpad(v_new, jnp.float32),
            unpad(w_new, w.dtype))
