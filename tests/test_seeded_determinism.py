"""Seeded-determinism regression: two ``launch.train`` runs with the
same seed must produce bitwise-identical loss curves, on the jnp
reference backend and the pallas slab engine alike.

This guards the round's PRNG contract (all randomness flows from
``fold_in(key(seed+1), round)``) — exactly the contract the sharded
engine's per-shard keying rework must preserve (its own bitwise rerun
check lives in repro.launch.shard_check). Runs are separate processes on
purpose: determinism must hold across interpreter restarts, not just
within one jitted session.
"""

import json
import os
import subprocess
import sys

import pytest

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

TRAIN_ARGS = ["--preset", "tiny", "--rounds", "2", "--clients", "2",
              "--batch", "1", "--seq", "16", "--seed", "3",
              "--log-every", "1000"]


def _train(backend: str, out_path: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--backend", backend,
         "--history-out", out_path, *TRAIN_ARGS],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    with open(out_path) as f:
        return json.load(f)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_same_seed_same_curve(backend, tmp_path):
    h1 = _train(backend, str(tmp_path / "h1.json"))
    h2 = _train(backend, str(tmp_path / "h2.json"))
    assert len(h1) == len(h2) == 2
    # bitwise: json round-trips repr(float64(float32)) exactly
    for a, b in zip(h1, h2):
        assert a["loss"] == b["loss"], (a, b)
        assert a["grad_norm"] == b["grad_norm"], (a, b)
