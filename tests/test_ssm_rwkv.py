"""Recurrent cores: Mamba-style SSM (associative scan) and RWKV-6
(chunked WKV) against naive sequential references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.rwkv import RWKVConfig, _wkv_chunked
from repro.models.ssm import SSMConfig, ssm_decode_step, ssm_forward, ssm_init


def test_ssm_parallel_scan_equals_sequential_decode():
    """Running the O(1) decode step token-by-token must equal the
    associative-scan forward."""
    cfg = SSMConfig(d_model=24, d_inner=48, d_state=8)
    p = ssm_init(jax.random.key(0), cfg, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model), jnp.float32)
    y_par = ssm_forward(p, cfg, x)
    from repro.models.ssm import init_ssm_cache
    cache = init_ssm_cache(b, cfg, jnp.float32)
    outs = []
    for t in range(s):
        yt, cache = ssm_decode_step(p, cfg, x[:, t:t + 1], cache)
        outs.append(yt)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_ssm_prefill_state_continues_decode():
    cfg = SSMConfig(d_model=16, d_inner=32, d_state=4)
    p = ssm_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 10, cfg.d_model), jnp.float32)
    y_all = ssm_forward(p, cfg, x)
    y_pre, cache = ssm_forward(p, cfg, x[:, :7], return_state=True)
    outs = []
    for t in range(7, 10):
        yt, cache = ssm_decode_step(p, cfg, x[:, t:t + 1], cache)
        outs.append(yt)
    got = jnp.concatenate([y_pre] + outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_all),
                               rtol=2e-4, atol=2e-4)


def _wkv_naive(r, k, v, w, u):
    """Literal per-token recurrence: y_t = r_t (S + u k_t v_t^T);
    S = diag(w_t) S + k_t v_t^T. Shapes (B,S,H,D)."""
    b, s, h, d = r.shape
    S = np.zeros((b, h, d, d), np.float64)
    ys = np.zeros((b, s, h, d), np.float64)
    r, k, v, w = (np.asarray(t, np.float64) for t in (r, k, v, w))
    u = np.asarray(u, np.float64)
    for t in range(s):
        kv = np.einsum("bhd,bhe->bhde", k[:, t], v[:, t])
        ys[:, t] = np.einsum("bhd,bhde->bhe", r[:, t],
                             S + u[None, :, :, None] * kv)
        S = S * w[:, t][..., None] + kv
    return ys


@pytest.mark.parametrize("s,chunk", [(7, 4), (16, 4), (33, 8), (12, 16)])
def test_wkv_chunked_matches_naive(s, chunk):
    b, h, d = 2, 3, 8
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, d))) * 0.6 + 0.3
    u = jax.random.normal(jax.random.key(5), (h, d)) * 0.1
    y, s_fin = _wkv_chunked(r, k, v, w, u, chunk)
    y_ref = _wkv_naive(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    # final state matches too
    S = np.zeros((b, h, d, d), np.float64)
    rn, kn, vn, wn = (np.asarray(t, np.float64) for t in (r, k, v, w))
    for t in range(s):
        kv = np.einsum("bhd,bhe->bhde", kn[:, t], vn[:, t])
        S = S * wn[:, t][..., None] + kv
    np.testing.assert_allclose(np.asarray(s_fin), S, rtol=1e-4, atol=1e-4)


def test_rwkv_full_block_decode_matches_forward():
    """Integration: rwkv block forward == prefill + stepwise decode."""
    from repro.models.rwkv import (init_rwkv_cache,
                                   time_mix_decode, time_mix_forward,
                                   time_mix_init)
    cfg = RWKVConfig(d_model=16, n_heads=2, d_ff=32, lora_rank=8, chunk=4)
    pt = time_mix_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 9, 16), jnp.float32)
    y_fwd = time_mix_forward(pt, cfg, x)
    cache = init_rwkv_cache(1, cfg, jnp.float32)
    outs = []
    c = cache
    for t in range(9):
        yt, c = time_mix_decode(pt, cfg, x[:, t:t + 1], c)
        outs.append(yt)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_fwd), np.asarray(y_dec),
                               rtol=2e-4, atol=2e-4)
