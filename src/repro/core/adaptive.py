"""ADOTA server optimizers (Algorithm 1 of the paper), as composable
init/update transforms over parameter pytrees.

All optimizers consume the *noisy OTA-aggregated* global gradient
``g_t`` (Eq. 7) and produce the new global model:

    Delta_t = beta1 * Delta_{t-1} + (1 - beta1) * g_t            (Eq. 8)
    v_t     = v_{t-1} + |Delta_t|^alpha                          (AdaGrad-OTA, Eq. 9)
    v_t     = beta2 * v_{t-1} + (1 - beta2) * |Delta_t|^alpha    (Adam-OTA,   Eq. 10)
    w_{t+1} = w_t - eta * Delta_t / (v_t + eps)^{1/alpha}        (Eq. 11)

The alpha-power / alpha-root are entrywise; ``alpha`` is the interference
tail index (estimated online via ``repro.core.tail_index`` in practice,
Remark 3). With ``alpha == 2`` these reduce to standard AdaGrad / an
Adam variant (eps inside the root), which the tests assert.

Baselines implemented for the paper's comparisons: FedAvgM (server
momentum SGD — the paper's main baseline) and plain FedAvg/SGD. A
beyond-paper ``yogi_ota`` (sign-based second-moment update, Reddi et al.
2020, generalized with the alpha-power) is provided as an extension.

Two execution backends, selected by ``AdaptiveConfig.backend``:

* ``"jnp"`` (default) — the per-leaf ``jax.tree.map`` reference above;
  readable, differentiable, and the parity oracle.
* ``"pallas"`` — the slab engine: (params, Delta, nu, g) are flattened
  through ``repro.core.slab`` into contiguous f32 slabs and the whole
  model is updated by ONE fused ``adaptive_update_slab`` kernel launch
  (one read-modify-write HBM pass) instead of a ~10-op chain per leaf.
  State trees are restored afterwards, so checkpoints, ``ServerOptState``
  structure, and results match the jnp backend to f32 rounding.

To add a new fused optimizer: implement its update rule as a mode in
``repro.kernels.adaptive_update`` (+ the oracle in ``kernels.ref``),
register the optimizer here, and map its name in ``_SLAB_MODES``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.slab import SlabSpec, make_slab_spec, slab_to_tree, tree_to_slab

PyTree = Any


class ServerOptState(NamedTuple):
    step: jax.Array          # scalar int32 round counter
    delta: PyTree            # first moment Delta_t (momentum)
    nu: PyTree               # second "moment" v_t (alpha-power accumulator)


class ServerOptimizer(NamedTuple):
    init: Callable[[PyTree], ServerOptState]
    # update(g, state, params, alpha=None): ``alpha`` optionally overrides
    # the config's tail index with a traced scalar — the closed-loop
    # tracked estimate. None (the default) keeps the static cfg.alpha.
    update: Callable[..., tuple]
    name: str


def _zeros_like_tree(params: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def _abs_pow(x: jax.Array, alpha) -> jax.Array:
    """Entrywise |x|^alpha, safe at x == 0 for fractional alpha."""
    ax = jnp.abs(x)
    # |x|^alpha = exp(alpha*log|x|) underflows fine but grad at 0 is nan for
    # alpha<1 in log-space; use power on the clamped value and zero-fill.
    return jnp.where(ax == 0, jnp.zeros_like(ax), ax ** alpha)


def _alpha_root(x: jax.Array, alpha) -> jax.Array:
    """Entrywise x^{1/alpha} for x >= 0."""
    return jnp.maximum(x, 0.0) ** (1.0 / alpha)


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Hyper-parameters of the ADOTA family (paper Sec. IV-B, Sec. VI)."""

    optimizer: str = "adam_ota"   # adagrad_ota | adam_ota | amsgrad_ota |
                                  # yogi_ota | fedavgm | fedavg
    lr: float = 1e-2              # eta
    beta1: float = 0.9            # momentum on Delta_t
    beta2: float = 0.3            # Adam-OTA amortization (paper fig.4 best: 0.3)
    alpha: Any = 1.5              # interference tail index used in v-update:
                                  # a float (the server assumes it knows the
                                  # channel's tail) or "auto" — the closed
                                  # estimation loop (paper Remark 3): the
                                  # slab-resident loops estimate alpha online
                                  # from the fused pilot statistics, carry
                                  # the EMA in SlabTrainState.alpha_hat and
                                  # feed it back into the update as a traced
                                  # scalar. Float configs are bitwise-
                                  # unchanged.
    alpha_ema: float = 0.1        # EMA weight of the per-round log-moment
                                  # estimate when alpha == "auto"
    eps: float = 1e-8             # ill-conditioning guard (inside the root)
    momentum: float = 0.9         # FedAvgM server momentum
    backend: str = "jnp"          # "jnp": per-leaf tree.map reference;
                                  # "pallas": one fused adaptive_update_slab
                                  # launch over the whole model slab;
                                  # "pallas_sharded": the slab round is
                                  # distributed over a device mesh
                                  # (repro.core.shard) — outside shard_map
                                  # this behaves like "pallas".
    interpret: Optional[bool] = None   # Pallas interpret mode; None (the
                                       # default) auto-selects from the
                                       # platform (compiled on TPU only;
                                       # see repro.kernels.interpret).

    def __post_init__(self):
        if self.backend not in ("jnp", "pallas", "pallas_sharded"):
            raise ValueError(f"unknown optimizer backend: {self.backend}")
        if isinstance(self.alpha, str) and self.alpha != "auto":
            raise ValueError(
                f'alpha must be a float tail index or "auto" (online '
                f'tracking), got {self.alpha!r}')
        if not (0.0 < self.alpha_ema <= 1.0):
            raise ValueError(
                f"alpha_ema must be in (0, 1], got {self.alpha_ema}")

    @property
    def track_alpha(self) -> bool:
        """True when the optimizer's tail index is estimated online."""
        return self.alpha == "auto"

    def resolve_alpha(self, alpha):
        """The alpha this update actually uses: an explicit (possibly
        traced) override wins; otherwise the static config float. A
        tracking config with no override is a contract violation — the
        caller was supposed to thread the resident ``alpha_hat`` in."""
        if alpha is not None:
            return alpha
        if self.track_alpha:
            raise ValueError(
                'AdaptiveConfig.alpha == "auto" needs the tracked alpha '
                'threaded into the update (the slab-resident loops do '
                'this; the per-round pytree API has no resident alpha_hat '
                'to carry the EMA across rounds)')
        return self.alpha


def _apply_update(params: PyTree, delta: PyTree, nu: PyTree, lr, alpha, eps) -> PyTree:
    def upd(w, d, v):
        denom = _alpha_root(v + eps, alpha)
        return (w - lr * d / denom).astype(w.dtype)
    return jax.tree.map(upd, params, delta, nu)


def adagrad_ota(cfg: AdaptiveConfig) -> ServerOptimizer:
    """AdaGrad-OTA: cumulative alpha-power second moment (Eq. 9)."""

    def init(params):
        return ServerOptState(
            step=jnp.zeros((), jnp.int32),
            delta=_zeros_like_tree(params, jnp.float32),
            nu=_zeros_like_tree(params, jnp.float32),
        )

    def update(g, state, params, alpha=None):
        a = cfg.resolve_alpha(alpha)
        delta = jax.tree.map(
            lambda d, gi: cfg.beta1 * d + (1.0 - cfg.beta1) * gi.astype(jnp.float32),
            state.delta, g)
        nu = jax.tree.map(lambda v, d: v + _abs_pow(d, a), state.nu, delta)
        new_params = _apply_update(params, delta, nu, cfg.lr, a, cfg.eps)
        return new_params, ServerOptState(state.step + 1, delta, nu)

    return ServerOptimizer(init, update, "adagrad_ota")


def adam_ota(cfg: AdaptiveConfig) -> ServerOptimizer:
    """Adam-OTA: exponential-moving-average alpha-power second moment (Eq. 10)."""

    def init(params):
        return ServerOptState(
            step=jnp.zeros((), jnp.int32),
            delta=_zeros_like_tree(params, jnp.float32),
            nu=_zeros_like_tree(params, jnp.float32),
        )

    def update(g, state, params, alpha=None):
        a = cfg.resolve_alpha(alpha)
        delta = jax.tree.map(
            lambda d, gi: cfg.beta1 * d + (1.0 - cfg.beta1) * gi.astype(jnp.float32),
            state.delta, g)
        nu = jax.tree.map(
            lambda v, d: cfg.beta2 * v + (1.0 - cfg.beta2) * _abs_pow(d, a),
            state.nu, delta)
        new_params = _apply_update(params, delta, nu, cfg.lr, a, cfg.eps)
        return new_params, ServerOptState(state.step + 1, delta, nu)

    return ServerOptimizer(init, update, "adam_ota")


def amsgrad_ota(cfg: AdaptiveConfig) -> ServerOptimizer:
    """Beyond-paper: AMSGrad-style non-decreasing denominator with the
    alpha-power. v follows Adam-OTA's EMA, but the stepsize divides by the
    running MAX of v — restoring AdaGrad-OTA's monotone-stepsize property
    (the ingredient behind its ln(T)/T^{1-1/a} guarantee) while keeping
    Adam-OTA's recency weighting."""

    def init(params):
        z = _zeros_like_tree(params, jnp.float32)
        return ServerOptState(step=jnp.zeros((), jnp.int32), delta=z,
                              nu={"v": z, "vmax": _zeros_like_tree(
                                  params, jnp.float32)})

    def update(g, state, params, alpha=None):
        a = cfg.resolve_alpha(alpha)
        delta = jax.tree.map(
            lambda d, gi: cfg.beta1 * d + (1.0 - cfg.beta1) * gi.astype(jnp.float32),
            state.delta, g)
        v = jax.tree.map(
            lambda v_, d: cfg.beta2 * v_ + (1.0 - cfg.beta2) * _abs_pow(d, a),
            state.nu["v"], delta)
        vmax = jax.tree.map(jnp.maximum, state.nu["vmax"], v)
        new_params = _apply_update(params, delta, vmax, cfg.lr, a,
                                   cfg.eps)
        return new_params, ServerOptState(state.step + 1, delta,
                                          {"v": v, "vmax": vmax})

    return ServerOptimizer(init, update, "amsgrad_ota")


def yogi_ota(cfg: AdaptiveConfig) -> ServerOptimizer:
    """Beyond-paper: Yogi-style additive second-moment with alpha-power.

    v_t = v_{t-1} - (1-beta2) * sign(v_{t-1} - |Delta_t|^a) * |Delta_t|^a
    Keeps the slow, sign-controlled v growth of Yogi (Zaheer et al. 2018 /
    Reddi et al. 2020 FedYogi) while inheriting the heavy-tail-aware
    alpha-power of ADOTA.
    """

    def init(params):
        return ServerOptState(
            step=jnp.zeros((), jnp.int32),
            delta=_zeros_like_tree(params, jnp.float32),
            nu=_zeros_like_tree(params, jnp.float32),
        )

    def update(g, state, params, alpha=None):
        a = cfg.resolve_alpha(alpha)
        delta = jax.tree.map(
            lambda d, gi: cfg.beta1 * d + (1.0 - cfg.beta1) * gi.astype(jnp.float32),
            state.delta, g)

        def vupd(v, d):
            da = _abs_pow(d, a)
            return v - (1.0 - cfg.beta2) * jnp.sign(v - da) * da

        nu = jax.tree.map(vupd, state.nu, delta)
        new_params = _apply_update(params, delta, nu, cfg.lr, a, cfg.eps)
        return new_params, ServerOptState(state.step + 1, delta, nu)

    return ServerOptimizer(init, update, "yogi_ota")


def fedavgm(cfg: AdaptiveConfig) -> ServerOptimizer:
    """FedAvgM baseline (Hsu et al. 2019): server momentum SGD on g_t."""

    def init(params):
        return ServerOptState(
            step=jnp.zeros((), jnp.int32),
            delta=_zeros_like_tree(params, jnp.float32),
            nu=jnp.zeros((), jnp.float32),   # unused
        )

    def update(g, state, params, alpha=None):
        # alpha accepted for interface uniformity; momentum SGD never
        # uses the tail index.
        delta = jax.tree.map(
            lambda d, gi: cfg.momentum * d + gi.astype(jnp.float32), state.delta, g)
        new_params = jax.tree.map(
            lambda w, d: (w - cfg.lr * d).astype(w.dtype), params, delta)
        return new_params, ServerOptState(state.step + 1, delta, state.nu)

    return ServerOptimizer(init, update, "fedavgm")


def fedavg(cfg: AdaptiveConfig) -> ServerOptimizer:
    """Plain FedAvg/SGD on the OTA gradient."""

    def init(params):
        return ServerOptState(
            step=jnp.zeros((), jnp.int32),
            delta=jnp.zeros((), jnp.float32),
            nu=jnp.zeros((), jnp.float32),
        )

    def update(g, state, params, alpha=None):
        new_params = jax.tree.map(
            lambda w, gi: (w - cfg.lr * gi).astype(w.dtype), params, g)
        return new_params, ServerOptState(state.step + 1, state.delta, state.nu)

    return ServerOptimizer(init, update, "fedavg")


_REGISTRY = {
    "adagrad_ota": adagrad_ota,
    "adam_ota": adam_ota,
    "amsgrad_ota": amsgrad_ota,
    "yogi_ota": yogi_ota,
    "fedavgm": fedavgm,
    "fedavg": fedavg,
}

# Optimizer name -> fused-kernel mode of repro.kernels.adaptive_update.
_SLAB_MODES = {
    "adagrad_ota": "adagrad",
    "adam_ota": "adam",
    "amsgrad_ota": "amsgrad",
    "yogi_ota": "yogi",
    "fedavgm": "momentum",
    "fedavg": "sgd",
}


def state_slab_rows(cfg: AdaptiveConfig) -> Tuple[str, ...]:
    """Names of the optimizer-state slabs the fused kernel carries, in
    the fixed row order used by ``pack_state_slabs``/``slab_update_slabs``.
    Empty for sgd; ("delta",) for momentum; ("delta", "nu", "vmax") for
    amsgrad; ("delta", "nu") otherwise."""
    mode = _SLAB_MODES[cfg.optimizer]
    if mode == "sgd":
        return ()
    if mode == "momentum":
        return ("delta",)
    if mode == "amsgrad":
        return ("delta", "nu", "vmax")
    return ("delta", "nu")


def pack_state_slabs(cfg: AdaptiveConfig, spec: SlabSpec,
                     state: ServerOptState) -> Tuple[jax.Array, ...]:
    """Flatten the optimizer state into f32 slabs, ``state_slab_rows``
    order. The slabs share ``spec``'s layout (and hence its shard-aligned
    padding), so the sharded engine can slice them per device.

    Since the slab-resident loop (``repro.core.slab_state``) this is an
    init/boundary-only conversion: the multi-round hot path keeps the
    slabs resident and never re-packs between rounds; only the
    pytree-per-round API (``apply_slab_update``) still calls it each
    round."""
    rows = state_slab_rows(cfg)
    amsgrad = "vmax" in rows     # nu is {"v": tree, "vmax": tree} then
    out = []
    for name in rows:
        if name == "delta":
            out.append(tree_to_slab(spec, state.delta))
        elif name == "nu":
            out.append(tree_to_slab(spec,
                                    state.nu["v"] if amsgrad else state.nu))
        else:  # vmax
            out.append(tree_to_slab(spec, state.nu["vmax"]))
    return tuple(out)


def unpack_state_slabs(cfg: AdaptiveConfig, spec: SlabSpec,
                       state: ServerOptState,
                       slabs: Tuple[jax.Array, ...]) -> ServerOptState:
    """Inverse of ``pack_state_slabs``: restore the state pytrees (f32,
    ``cast=False``) and bump the round counter. Modes that carry no
    delta/nu keep the previous (placeholder) values. Boundary-only, like
    ``pack_state_slabs`` (the resident loop uses
    ``slab_state.unpack_train_state`` at eval/checkpoint boundaries
    instead)."""
    rows = state_slab_rows(cfg)
    named = dict(zip(rows, slabs))
    delta = (slab_to_tree(spec, named["delta"], cast=False)
             if "delta" in named else state.delta)
    if "vmax" in named:
        nu = {"v": slab_to_tree(spec, named["nu"], cast=False),
              "vmax": slab_to_tree(spec, named["vmax"], cast=False)}
    elif "nu" in named:
        nu = slab_to_tree(spec, named["nu"], cast=False)
    else:
        nu = state.nu
    return ServerOptState(state.step + 1, delta, nu)


def slab_update_slabs(cfg: AdaptiveConfig, g_slab: jax.Array,
                      state_slabs: Tuple[jax.Array, ...], w_slab: jax.Array,
                      alpha=None
                      ) -> Tuple[Tuple[jax.Array, ...], jax.Array]:
    """ONE fused ``adaptive_update_slab`` launch on raw 1-D slabs.

    ``state_slabs`` is in ``state_slab_rows`` order; the slabs may be the
    full model or any lane-aligned slice of it (the sharded engine passes
    each device's local slab shard). ``alpha`` optionally overrides
    ``cfg.alpha`` with the tracked traced scalar (mandatory when
    ``cfg.alpha == "auto"``). Returns ``(new_state_slabs, w')``.
    """
    # repro-lint: lazy-import (cycle: kernels.adaptive_update imports
    # core.adaptive for _abs_pow)
    from repro.kernels.adaptive_update import adaptive_update_slab

    mode = _SLAB_MODES[cfg.optimizer]
    a = 2.0 if mode in ("momentum", "sgd") else cfg.resolve_alpha(alpha)
    kw = dict(lr=cfg.lr,
              beta1=cfg.momentum if mode == "momentum" else cfg.beta1,
              beta2=cfg.beta2, alpha=a, eps=cfg.eps, mode=mode,
              interpret=cfg.interpret)
    if mode == "sgd":
        (w_n,) = adaptive_update_slab(g_slab, None, None, w_slab, **kw)
        return (), w_n
    if mode == "momentum":
        d_n, w_n = adaptive_update_slab(g_slab, state_slabs[0], None, w_slab,
                                        **kw)
        return (d_n,), w_n
    if mode == "amsgrad":
        d_s, v_s, m_s = state_slabs
        d_n, v_n, m_n, w_n = adaptive_update_slab(g_slab, d_s, v_s, w_slab,
                                                  nu_max=m_s, **kw)
        return (d_n, v_n, m_n), w_n
    d_s, v_s = state_slabs
    d_n, v_n, w_n = adaptive_update_slab(g_slab, d_s, v_s, w_slab, **kw)
    return (d_n, v_n), w_n


def apply_slab_update(cfg: AdaptiveConfig, spec: SlabSpec, g_slab: jax.Array,
                      state: ServerOptState, params: PyTree, alpha=None):
    """Slab-engine server update: ONE fused kernel over the whole model.

    ``g_slab`` is the (spec.padded,) f32 aggregated gradient — typically
    straight out of ``ota_channel_slab`` so the slab stays the canonical
    representation between the two kernel launches of a round. params
    and optimizer state are flattened in, updated by a single
    ``adaptive_update_slab`` call, and restored to their pytree forms
    (params to their original dtypes, state to f32), so the result is
    interchangeable with the jnp backend's. ``alpha`` optionally
    overrides ``cfg.alpha`` with the tracked traced scalar.
    """
    w_s = tree_to_slab(spec, params)
    new_slabs, w_n = slab_update_slabs(cfg, g_slab, pack_state_slabs(
        cfg, spec, state), w_s, alpha=alpha)
    new_params = slab_to_tree(spec, w_n)
    return new_params, unpack_state_slabs(cfg, spec, state, new_slabs)


def _make_slab_update(cfg: AdaptiveConfig):
    """Tree-in/tree-out update that routes through ``apply_slab_update``."""

    def update(g, state, params, alpha=None):
        spec = make_slab_spec(params)
        return apply_slab_update(cfg, spec, tree_to_slab(spec, g), state,
                                 params, alpha=alpha)

    return update


def make_server_optimizer(cfg: AdaptiveConfig) -> ServerOptimizer:
    if cfg.optimizer not in _REGISTRY:
        raise ValueError(
            f"unknown server optimizer {cfg.optimizer!r}; options: {sorted(_REGISTRY)}")
    opt = _REGISTRY[cfg.optimizer](cfg)
    if cfg.backend == "jnp":
        return opt
    # "pallas" and "pallas_sharded" both use the fused slab update here:
    # the sharded round step (repro.core.shard) drives the kernels itself
    # inside shard_map and only uses this optimizer's ``init``.
    return ServerOptimizer(opt.init, _make_slab_update(cfg), opt.name)
