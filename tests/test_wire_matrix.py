"""Wire-format matrix (PR 7): {f32, int8, sign} uplink x {EF on/off}
x {f32, int8} downlink, across backends.

The acceptance contracts:

* every VALID cell of the matrix runs on jnp and pallas and the two
  engines agree at the cross-engine tier (1e-5) — the jnp cell is the
  op-mirrored oracle of the fused kernel cell;
* ``uplink="sign"`` is a deterministic 1-bit payload: sign bits +
  per-128-block mean-magnitude scales, op-mirrored in the ref oracle
  BITWISE, and it consumes NO stochastic-rounding draw (flipping
  ``stochastic_rounding`` cannot perturb a sign trajectory);
* error feedback carries the quantization residual
  ``e' = (a + e) - dequant(quant(a + e))`` in resident per-transmitter
  slab rows: it survives a checkpoint round-trip bitwise and recovers
  adam_ota convergence under the sign uplink (round count to the f32
  loss within 10%);
* the int8 downlink quantizes the model BROADCAST (what clients see)
  per-128-block with stochastic rounding keyed ``DL_FOLD`` off the
  round key; the server keeps the f32 master, and the helper is
  slice-local (quantize-then-slice == slice-then-quantize on lane
  boundaries — the sharded engine's correctness basis);
* the all-zero padded tail of a slab survives every wire format
  exactly: zero blocks keep scale 1 and payload 0 on the uplink, the
  downlink, and in the EF residual.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_slab_state, save_slab_state
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, downlink_quantize_slab,
                        downlink_sr_slab_inputs, init_train_state,
                        make_round_step, make_slab_round_step)
from repro.core.channel import DL_FOLD

N = 8
SHAPES = [(3, 45), (130,), (1,)]


def _params():
    ks = jax.random.split(jax.random.key(0), len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _batches(params, n=N):
    return jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (n,) + p.shape),
        params)


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _configs(uplink="f32", ef=False, downlink="f32", xi=0.1, **fl_kw):
    ch = OTAChannelConfig(alpha=1.5, xi_scale=xi, downlink=downlink,
                          uplink=UplinkConfig(mode=uplink,
                                              error_feedback=ef))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    return ch, ad, FLConfig(n_clients=fl_kw.pop("n_clients", N), **fl_kw)


def _trajectory(ch, ad, fl, backend, rounds=2, params=None, batches=None):
    params = params or _params()
    batches = batches if batches is not None else _batches(params)
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend=backend)
    st = init_train_state(ad, params,
                          error_feedback=ch.uplink.error_feedback)
    ms = None
    for t in range(rounds):
        st, ms = step(st, jax.random.fold_in(jax.random.key(7), t), batches)
    return st, ms


def _state_arrays(st):
    out = [st.w, *st.opt, st.alpha_hat]
    if st.ef is not None:
        out.append(st.ef)
    return out


# Every valid cell: EF needs a residual, so f32+EF does not exist.
CELLS = [(u, e, dl)
         for u in ("f32", "int8", "sign")
         for e in (False, True)
         for dl in ("f32", "int8")
         if not (u == "f32" and e)]


# ---------------------------------------------------------------------------
# Tentpole: the full matrix, jnp oracle vs fused pallas kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("uplink,ef,downlink", CELLS)
def test_matrix_cell_jnp_pallas_parity(uplink, ef, downlink):
    """Each matrix cell runs on both engines and lands on the same
    trajectory at the cross-engine tier; the EF slab (when on) is part
    of the compared state."""
    ch, ad, fl = _configs(uplink, ef, downlink)
    st_j, m_j = _trajectory(ch, ad, fl, "jnp")
    st_p, m_p = _trajectory(ch, ad, fl, "pallas")
    assert (st_j.ef is not None) == ef
    assert (st_p.ef is not None) == ef
    for a, b in zip(_state_arrays(st_j), _state_arrays(st_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(m_j.loss), float(m_p.loss), rtol=1e-5)
    if ef:
        # A quantized round leaves a real residual behind.
        assert float(jnp.max(jnp.abs(st_p.ef))) > 0.0


@pytest.mark.parametrize("uplink,ef,downlink",
                         [("int8", True, "f32"), ("sign", True, "int8")])
def test_matrix_cell_streamed_parity(uplink, ef, downlink):
    """The same cells through the STREAMED round body (chunked
    accumulating transmit + partial participation): the EF rows ride
    the scan carry on both engines."""
    ch, ad, fl = _configs(uplink, ef, downlink, client_chunk=3,
                          sample_rate=0.75)
    st_j, m_j = _trajectory(ch, ad, fl, "jnp", rounds=3)
    st_p, m_p = _trajectory(ch, ad, fl, "pallas", rounds=3)
    assert float(m_j.n_participants) == float(m_p.n_participants)
    for a, b in zip(_state_arrays(st_j), _state_arrays(st_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_matrix_cell_sharded_mesh1_matches_pallas():
    """The (1,)-mesh sharded engine runs the far-corner cell
    (sign + EF + int8 downlink) as the same program as the
    single-device pallas engine: near-exact trajectory, EF slab
    included — quantize/EF/broadcast all happen on identical slices.
    (P > 1 meshes quantize per-transmitter partials and sit in the
    loose tier; shard_check covers them on forced host devices.)"""
    from repro.core import make_slab_round_runner
    from repro.launch.mesh import make_client_mesh
    ch, ad, fl = _configs("sign", True, "int8")
    params = _params()
    batches = _batches(params)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(7), t)
                      for t in range(2)])
    stacked = jax.tree.map(lambda b: jnp.stack([b] * 2), batches)
    run_p = make_slab_round_runner(_loss_fn, ch, ad, fl, backend="pallas")
    run_s = make_slab_round_runner(_loss_fn, ch, ad, fl,
                                   backend="pallas_sharded",
                                   mesh=make_client_mesh((1,)))
    st_p, ms_p = run_p(init_train_state(ad, params, error_feedback=True),
                       keys, stacked)
    st_s, ms_s = run_s(init_train_state(ad, params, shards=1,
                                        error_feedback=True),
                       keys, stacked)
    assert st_p.ef is not None and st_s.ef is not None
    for a, b in zip(_state_arrays(st_p), _state_arrays(st_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ms_p.loss), np.asarray(ms_s.loss),
                               rtol=1e-5)


def test_f32_cell_ignores_new_fields():
    """The PR 1-6 baseline cell is untouched: a config spelled with the
    PR 7 defaults is the IDENTICAL object graph, the state carries no
    EF slab, and the trajectory is bitwise the pre-matrix one."""
    ch_new, ad, fl = _configs("f32", False, "f32")
    ch_old = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                              uplink=UplinkConfig(mode="f32"))
    st_a, _ = _trajectory(ch_new, ad, fl, "pallas")
    st_b, _ = _trajectory(ch_old, ad, fl, "pallas")
    assert st_a.ef is None and st_b.ef is None
    for a, b in zip(_state_arrays(st_a), _state_arrays(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_invalid_cells_rejected():
    with pytest.raises(ValueError, match="residual"):
        UplinkConfig(mode="f32", error_feedback=True)
    with pytest.raises(ValueError):
        OTAChannelConfig(downlink="int4")
    with pytest.raises(ValueError):
        UplinkConfig(mode="fp8")
    # The pytree-per-round API has no resident EF rows / broadcast hook.
    ch, ad, fl = _configs("int8", ef=True)
    with pytest.raises(ValueError):
        make_round_step(_loss_fn, ch, ad, fl, backend="jnp")
    ch2, _, _ = _configs("f32", downlink="int8")
    with pytest.raises(ValueError):
        make_round_step(_loss_fn, ch2, ad, fl, backend="jnp")
    # An EF config refuses a state without the slab (e.g. stale init).
    ch3, ad3, fl3 = _configs("sign", ef=True)
    step = make_slab_round_step(_loss_fn, ch3, ad3, fl3, backend="jnp")
    st = init_train_state(ad3, _params())            # no error_feedback
    with pytest.raises(ValueError):
        step(st, jax.random.key(0), _batches(_params()))


# ---------------------------------------------------------------------------
# Sign payload: kernel == ref bitwise, no SR draw
# ---------------------------------------------------------------------------

def test_sign_transmit_matches_ref():
    """Kernel vs op-mirrored oracle under the documented quantized
    contract: scales at f32 rounding, payloads exactly equal except
    where the partial sits within f32 rounding of zero (a sign can
    only flip there), residual reconstructing the EF-adjusted partial."""
    from repro.kernels.ota_channel import ota_transmit_slab
    from repro.kernels.ref import ota_transmit_ref
    d, n = 512, 6
    g = jax.random.normal(jax.random.key(0), (n, d))
    h = jax.random.uniform(jax.random.key(1), (n,), minval=0.5, maxval=1.5)
    e = 0.01 * jax.random.normal(jax.random.key(2), (d,))
    for ef in (None, e):
        q_k, s_k, r_k = ota_transmit_slab(
            g, h, n_total=n, quantize=True, qmode="sign", ef=ef,
            return_residual=True, interpret=True)
        q_r, s_r, r_r = ota_transmit_ref(
            g, h, n_total=n, quantize=True, qmode="sign", ef=ef,
            return_residual=True)
        np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                                   rtol=1e-6)
        same = np.asarray(q_k) == np.asarray(q_r)
        assert same.mean() > 0.99, f"{(~same).sum()} sign flips"
        np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r),
                                   rtol=1e-4, atol=1e-6)
    assert q_k.dtype == jnp.int8
    assert set(np.unique(np.asarray(q_k))) <= {-1, 0, 1}
    # Per-block scale is the mean |block| of the (EF-adjusted) partial.
    agg = np.asarray(jnp.sum(h[:, None] * g, axis=0) / n + e)
    np.testing.assert_allclose(np.asarray(s_k),
                               np.abs(agg.reshape(-1, 128)).mean(1),
                               rtol=1e-5)
    # EF residual identity: dequant + residual reconstructs a + e.
    np.testing.assert_allclose(
        np.asarray(q_k).astype(np.float32)
        * np.repeat(np.asarray(s_k), 128) + np.asarray(r_k),
        agg, rtol=1e-5, atol=1e-6)


def test_sign_consumes_no_sr_draw():
    """Sign is deterministic: toggling stochastic_rounding — which
    redraws SR uniforms for int8 — cannot move a sign trajectory."""
    ch_a, ad, fl = _configs("sign", ef=True)
    ch_b = OTAChannelConfig(
        alpha=1.5, xi_scale=0.1,
        uplink=UplinkConfig(mode="sign", error_feedback=True,
                            stochastic_rounding=False))
    st_a, _ = _trajectory(ch_a, ad, fl, "pallas")
    st_b, _ = _trajectory(ch_b, ad, fl, "pallas")
    for a, b in zip(_state_arrays(st_a), _state_arrays(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Zero-tail wire survival
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qmode", ["int8", "sign"])
def test_zero_tail_survives_uplink(qmode):
    """The padded tail of a slab is all-zero blocks: scale 1, payload
    0, residual 0 — the tail comes back EXACTLY zero, so padding can
    never leak into real coordinates."""
    from repro.kernels.ref import ota_transmit_ref
    d, live = 640, 300
    g = jnp.where(jnp.arange(d) < live,
                  jax.random.normal(jax.random.key(0), (d,)), 0.0)[None, :]
    h = jnp.ones((1,))
    r = jax.random.uniform(jax.random.key(1), (d,))
    q, s, resid = ota_transmit_ref(g, h, n_total=1, quantize=True,
                                   qmode=qmode, r=r, ef=None,
                                   return_residual=True)
    tail_blocks = np.asarray(s)[(live + 127) // 128:]
    np.testing.assert_array_equal(tail_blocks, np.ones_like(tail_blocks))
    np.testing.assert_array_equal(np.asarray(q)[384:], np.zeros(d - 384))
    np.testing.assert_array_equal(np.asarray(resid)[384:],
                                  np.zeros(d - 384))


def test_zero_tail_survives_downlink():
    d, live = 640, 300
    w = jnp.where(jnp.arange(d) < live,
                  jax.random.normal(jax.random.key(0), (d,)), 0.0)
    r = downlink_sr_slab_inputs(jax.random.key(5), d)
    dq = downlink_quantize_slab(w, r)
    np.testing.assert_array_equal(np.asarray(dq)[384:], np.zeros(d - 384))
    # Per-block reconstruction error is bounded by one step (the scale).
    s = np.abs(np.asarray(w).reshape(-1, 128)).max(1) / 127.0
    err = np.abs(np.asarray(dq - w)).reshape(-1, 128).max(1)
    assert np.all(err <= np.maximum(s, 1e-7) + 1e-7)


def test_downlink_sr_keyed_dl_fold_and_slice_local():
    key = jax.random.key(9)
    r = downlink_sr_slab_inputs(key, 256)
    np.testing.assert_array_equal(
        np.asarray(r),
        np.asarray(jax.random.uniform(jax.random.fold_in(key, DL_FOLD),
                                      (256,))))
    # Lane-aligned slice-locality: quantize-then-slice == slice-then-
    # quantize — what lets each shard quantize its own slice before the
    # all_gather.
    w = jax.random.normal(jax.random.key(2), (512,))
    full = downlink_quantize_slab(w, downlink_sr_slab_inputs(key, 512))
    lo = downlink_quantize_slab(w[:256],
                                downlink_sr_slab_inputs(key, 512)[:256])
    hi = downlink_quantize_slab(w[256:],
                                downlink_sr_slab_inputs(key, 512)[256:])
    np.testing.assert_array_equal(np.asarray(full),
                                  np.concatenate([np.asarray(lo),
                                                  np.asarray(hi)]))


# ---------------------------------------------------------------------------
# Error feedback: checkpoint round-trip + convergence recovery
# ---------------------------------------------------------------------------

def test_ef_checkpoint_resume_bitwise(tmp_path):
    """Save mid-trajectory with a live EF slab, resume, and land on the
    uninterrupted trajectory BITWISE — the residual is state, losing it
    at a restart would re-introduce the quantization bias EF exists to
    cancel."""
    ch, ad, fl = _configs("sign", ef=True, downlink="int8")
    params = _params()
    batches = _batches(params)
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend="pallas")
    st = init_train_state(ad, params, error_feedback=True)
    keys = [jax.random.fold_in(jax.random.key(7), t) for t in range(4)]
    for k in keys[:2]:
        st, _ = step(st, k, batches)
    path = os.path.join(tmp_path, "round_2.npz")
    save_slab_state(path, st)
    resumed, _ = load_slab_state(path, st.spec)
    assert resumed.ef is not None
    np.testing.assert_array_equal(np.asarray(resumed.ef), np.asarray(st.ef))
    for k in keys[2:]:
        st, _ = step(st, k, batches)
        resumed, _ = step(resumed, k, batches)
    for a, b in zip(_state_arrays(st), _state_arrays(resumed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sign_ef_recovers_adam_convergence():
    """The acceptance bar: under the 1-bit uplink, adam_ota with EF
    reaches the f32 loss level within 10% of the f32 round count; the
    EF-off sign run never gets there in the horizon (the residual the
    1-bit payload discards each round is exactly what EF carries)."""
    params = _params()
    batches = _batches(params)
    horizon, target = 30, 3.5

    def rounds_to_target(uplink, ef):
        ch, ad, fl = _configs(uplink, ef, xi=0.02)
        step = make_slab_round_step(_loss_fn, ch, ad, fl, backend="jnp")
        st = init_train_state(ad, params, error_feedback=ef)
        for t in range(horizon):
            st, m = step(st, jax.random.fold_in(jax.random.key(7), t),
                         batches)
            if float(m.loss) < target:
                return t + 1
        return None

    r_f32 = rounds_to_target("f32", False)
    r_ef = rounds_to_target("sign", True)
    r_bare = rounds_to_target("sign", False)
    assert r_f32 is not None
    assert r_ef is not None and r_ef <= int(np.ceil(1.1 * r_f32)), \
        (r_f32, r_ef)
    assert r_bare is None, r_bare   # sign alone stalls above the target
