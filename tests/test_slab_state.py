"""Slab-resident training state: boundary conversions, the resident
round loops, and SlabTrainState checkpointing.

The multi-round contracts (PR 3):

* ``pack_train_state`` / ``unpack_train_state`` round-trip exactly for
  every optimizer (params in original dtypes, state in f32, placeholder
  leaves preserved);
* the resident slab loop (``make_slab_round_runner`` +
  ``run_rounds_slab``) reproduces the per-round pytree driver's
  trajectory from the same key (identical PRNG draws, f32 rounding);
* ``save_slab_state`` -> ``load_slab_state`` -> continue is
  bitwise-identical to the uninterrupted run (even across different
  scan-chunk boundaries), and a drifted slab layout is refused.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint as ckpt
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, init_train_state, make_round_step,
                        make_slab_round_runner, make_slab_round_step,
                        make_slab_spec, pack_train_state, run_rounds,
                        run_rounds_slab, unpack_train_state)

ALL_OPTIMIZERS = ["adagrad_ota", "adam_ota", "amsgrad_ota", "yogi_ota",
                  "fedavgm", "fedavg"]

SHAPES = [(3, 45), (130,), (1,), (257,)]


def _params(key):
    ks = jax.random.split(key, len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _assert_trees_equal(a, b, bitwise=True, tol=0.0):
    assert jax.tree.structure(a) == jax.tree.structure(b), (
        jax.tree.structure(a), jax.tree.structure(b))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       rtol=tol, atol=tol)


@pytest.mark.parametrize("optimizer", ALL_OPTIMIZERS)
def test_pack_unpack_round_trip(optimizer):
    params = _params(jax.random.key(0))
    ad = AdaptiveConfig(optimizer=optimizer)
    # run one real jnp update so the packed state holds non-trivial
    # values (and the placeholder leaves their canonical zeros)
    from repro.core import make_server_optimizer
    g = jax.tree.map(lambda p: jax.random.normal(jax.random.key(9), p.shape),
                     params)
    params, state = make_server_optimizer(ad).update(
        g, init_server(params, ad), params)
    spec = make_slab_spec(params)
    st = pack_train_state(ad, spec, params, state)
    p2, s2 = unpack_train_state(ad, st)
    _assert_trees_equal(params, p2)
    _assert_trees_equal(state, s2)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(s2)):
        assert a.dtype == b.dtype


@pytest.mark.parametrize("optimizer", ALL_OPTIMIZERS)
def test_init_train_state_matches_init_server(optimizer):
    params = _params(jax.random.key(1))
    ad = AdaptiveConfig(optimizer=optimizer)
    st = init_train_state(ad, params)
    p2, s2 = unpack_train_state(ad, st)
    _assert_trees_equal(params, p2)
    _assert_trees_equal(init_server(params, ad), s2)


def test_run_rounds_slab_matches_run_rounds():
    """The slab-resident host driver reproduces the pytree driver's
    trajectory from the same key (identical PRNG keying contract)."""
    params = _params(jax.random.key(2))
    n = 4
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)

    def batch_fn(t, key):
        return jax.tree.map(
            lambda p: jax.random.normal(jax.random.fold_in(key, 0),
                                        (n,) + p.shape), params)

    rs = make_round_step(_loss_fn, ch, ad, fl, backend="jnp")
    p_ref, s_ref, hist_ref = run_rounds(rs, params, init_server(params, ad),
                                        jax.random.key(11), batch_fn, 5)

    run = make_slab_round_runner(_loss_fn, ch, ad, fl, backend="pallas")
    st, hist = run_rounds_slab(run, init_train_state(ad, params),
                               jax.random.key(11), batch_fn, 5, chunk=2)
    p_res, s_res = unpack_train_state(ad, st)
    _assert_trees_equal(p_ref, p_res, bitwise=False, tol=1e-5)
    _assert_trees_equal(s_ref.delta, s_res.delta, bitwise=False, tol=1e-5)
    assert [h["round"] for h in hist] == [h["round"] for h in hist_ref]
    for a, b in zip(hist, hist_ref):
        np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)
        np.testing.assert_allclose(a["grad_norm"], b["grad_norm"], rtol=1e-4)


@pytest.mark.parametrize("optimizer", ["adam_ota", "amsgrad_ota", "fedavg"])
def test_checkpoint_resume_is_bitwise(optimizer, tmp_path):
    """save -> load -> continue == uninterrupted, bitwise, even though
    the resumed run scans with different chunk boundaries."""
    params = _params(jax.random.key(3))
    n = 2
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer=optimizer, lr=0.05, alpha=1.5, beta2=0.3)
    fl = FLConfig(n_clients=n)
    run = make_slab_round_runner(_loss_fn, ch, ad, fl, backend="pallas")
    keys = jnp.stack([jax.random.fold_in(jax.random.key(5), t)
                      for t in range(4)])
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(4), (4, n) + p.shape),
        params)

    # uninterrupted: one scanned chunk of 4 rounds
    st_full, _ = run(init_train_state(ad, params), keys, batches)

    # interrupted at round 2, checkpointed, resumed in chunks of 1
    first = jax.tree.map(lambda x: x[:2], batches)
    st_half, _ = run(init_train_state(ad, params), keys[:2], first)
    path = os.path.join(tmp_path, "round_2.npz")
    ckpt.save_slab_state(path, st_half, extra={"note": np.int32(7)})
    st_loaded, extra = ckpt.load_slab_state(path, st_half.spec)
    assert int(extra["note"]) == 7
    assert int(st_loaded.step) == 2
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend="pallas")
    st = st_loaded
    for t in (2, 3):
        st, _ = step(st, keys[t], jax.tree.map(lambda x: x[t], batches))

    _assert_trees_equal((st_full.step, st_full.w, st_full.opt),
                        (st.step, st.w, st.opt))


def test_train_cli_resume_is_bitwise(tmp_path):
    """launch.train --ckpt-dir/--resume: an interrupted + resumed run
    produces the same checkpoints and loss curve, bitwise, as an
    uninterrupted one — across separate processes (this also pins the
    host-side contract that batch draws are keyed by the absolute round
    index, not by call count)."""
    repo_root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                             ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(repo_root, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    base = ["--preset", "tiny", "--rounds", "4", "--clients", "2",
            "--batch", "1", "--seq", "16", "--seed", "3",
            "--log-every", "1000", "--scan-rounds", "3",
            "--ckpt-every", "2"]

    def train(extra):
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", *base, *extra],
            capture_output=True, text=True, cwd=repo_root, env=env,
            timeout=600)
        assert res.returncode == 0, res.stdout + res.stderr
        return res.stdout

    full_dir, part_dir = str(tmp_path / "full"), str(tmp_path / "part")
    train(["--ckpt-dir", full_dir,
           "--history-out", str(tmp_path / "h_full.json")])
    train(["--ckpt-dir", part_dir, "--rounds", "2"])
    out = train(["--ckpt-dir", part_dir, "--resume",
                 "--history-out", str(tmp_path / "h_resumed.json")])
    assert "resumed from" in out and "at round 2" in out

    a = np.load(os.path.join(full_dir, "round_4.npz"))
    b = np.load(os.path.join(part_dir, "round_4.npz"))
    assert set(a.files) == set(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k])
    with open(tmp_path / "h_full.json") as f:
        h_full = json.load(f)
    with open(tmp_path / "h_resumed.json") as f:
        h_res = json.load(f)
    assert [r["round"] for r in h_res] == [2, 3]
    for x, y in zip(h_full[2:], h_res):
        assert x["loss"] == y["loss"] and x["grad_norm"] == y["grad_norm"]


def test_load_slab_state_refuses_drifted_layout(tmp_path):
    params = _params(jax.random.key(6))
    ad = AdaptiveConfig(optimizer="adam_ota")
    st = init_train_state(ad, params)
    path = os.path.join(tmp_path, "round_1.npz")
    ckpt.save_slab_state(path, st)
    # same tree, different shard-aligned padding -> different layout
    drifted = make_slab_spec(params, shards=4)
    with pytest.raises(ValueError, match="layout mismatch"):
        ckpt.load_slab_state(path, drifted)
    # and a different model entirely
    other = make_slab_spec({"w": jnp.zeros((8, 8))})
    with pytest.raises(ValueError, match="layout mismatch"):
        ckpt.load_slab_state(path, other)
    # renamed keys with IDENTICAL shapes/dtypes/offsets: only the
    # treedef differs, and resuming would silently swap slab segments
    renamed = make_slab_spec({f"q{i}": v for i, (k, v) in
                              enumerate(sorted(params.items()))})
    with pytest.raises(ValueError, match="layout mismatch"):
        ckpt.load_slab_state(path, renamed)


def test_slab_state_is_a_pytree():
    params = _params(jax.random.key(7))
    ad = AdaptiveConfig(optimizer="adam_ota")
    st = init_train_state(ad, params)
    doubled = jax.tree.map(lambda x: x * 2, st)
    assert isinstance(doubled, type(st))
    assert doubled.spec == st.spec
    np.testing.assert_array_equal(np.asarray(doubled.w),
                                  2 * np.asarray(st.w))
    # jit caches on the static spec aux data
    f = jax.jit(lambda s: s.w.sum())
    f(st)


def test_mesh_shard_mismatch_is_rejected():
    """A state laid out for P shards cannot run on a Q-shard mesh."""
    from repro.compat import make_auto_mesh
    params = _params(jax.random.key(8))
    ad = AdaptiveConfig(optimizer="adam_ota")
    ch, fl = OTAChannelConfig(), FLConfig(n_clients=2)
    step = make_slab_round_step(_loss_fn, ch, ad, fl,
                                backend="pallas_sharded",
                                mesh=make_auto_mesh((1,), ("data",)))
    st = init_train_state(ad, params, shards=2)   # wrong layout for (1,)
    batches = jax.tree.map(
        lambda p: jnp.zeros((2,) + p.shape), params)
    with pytest.raises(ValueError, match="shards"):
        step(st, jax.random.key(0), batches)
