"""The closed alpha loop (PR 5): fused pilot statistics, the resident
alpha_hat EMA, the traced-alpha kernels, and checkpoint/resume of the
tracker.

Acceptance contract: with ``AdaptiveConfig.alpha = "auto"`` on a channel
at true alpha in {1.2, 1.5, 1.8}, ``RoundMetrics.alpha_hat`` converges
to within +-0.1 of the true tail index within 50 rounds on the jnp and
pallas engines (pallas_sharded parity at the usual 1e-5 vs jnp), while
static-alpha configs keep the exact pre-PR-5 code paths (no stats
output, alpha baked into the kernel) and the per-round pytree API
refuses "auto" instead of silently resetting the EMA every round.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint as ckpt
from repro.compat import make_auto_mesh
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_train_state, log_moment_stats,
                        make_round_step, make_slab_round_runner,
                        make_slab_round_step, make_slab_spec,
                        make_server_optimizer, unpack_train_state)
from repro.core.ota import interference_log_moment_stats

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SHAPES = [(64, 64), (257,), (1,)]


def _params(key):
    ks = jax.random.split(key, len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _run_tracked(backend, params, ch, ad, fl, rounds, mesh=None, shards=1):
    n = fl.n_clients
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3), (n,) + p.shape),
        params)
    run = make_slab_round_runner(_loss_fn, ch, ad, fl, backend=backend,
                                 mesh=mesh)
    st = init_train_state(ad, params, shards=shards)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(7), t)
                      for t in range(rounds)])
    stacked = jax.tree.map(lambda b: jnp.stack([b] * rounds), batches)
    return run(st, keys, stacked)


# ---------------------------------------------------------------------------
# Fused epilogue statistics: kernel == ref == per-leaf mirror.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alpha", [1.2, 1.5, 2.0])
def test_channel_kernel_stats_match_ref_and_samples(alpha):
    from repro.kernels.ota_channel import ota_channel_slab
    from repro.kernels.ref import ota_channel_ref
    from repro.core.channel import cms_inputs, cms_transform
    n, d = 4, 1664
    G = jax.random.normal(jax.random.key(0), (n, d))
    h = jax.random.uniform(jax.random.key(1), (n,), minval=0.5, maxval=1.5)
    u, e = cms_inputs(jax.random.key(2), (d,))
    out_k, st_k = ota_channel_slab(G, h, u, e, alpha=alpha, scale=0.3,
                                   pilot_stats=True)
    out_r, st_r = ota_channel_ref(G, h, u, e, alpha=alpha, scale=0.3,
                                  pilot_stats=True)
    # the main output is untouched by the epilogue
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(
        ota_channel_slab(G, h, u, e, alpha=alpha, scale=0.3)), rtol=0)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=1e-5)
    # and both equal the raw-sample reduction of the actual residual
    direct = log_moment_stats(0.3 * cms_transform(u, e, alpha))
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(direct),
                               rtol=1e-5)
    assert float(st_k[0]) == d   # every real entry bears interference


def test_receive_kernel_stats_match_ref():
    from repro.kernels.ota_channel import ota_receive_slab
    from repro.kernels.ref import ota_receive_ref
    from repro.core.channel import cms_inputs
    d = 1280
    q = jax.random.randint(jax.random.key(3), (2, d), -127, 128,
                           dtype=jnp.int8)
    s = jax.random.uniform(jax.random.key(4), (2, d // 128)) * 0.01
    u, e = cms_inputs(jax.random.key(5), (d,))
    out_k, st_k = ota_receive_slab(q, s, u, e, alpha=1.5, scale=0.2,
                                   pilot_stats=True)
    out_r, st_r = ota_receive_ref(q, s, u, e, alpha=1.5, scale=0.2,
                                  pilot_stats=True)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=1e-5)
    # scale 0 (disabled interference / the clean diagnostic wire):
    # nothing to measure
    _, st0 = ota_receive_slab(q, s, u, e, alpha=1.5, scale=0.0,
                              pilot_stats=True)
    assert float(st0[0]) == 0.0


def test_perleaf_stats_mirror_slab_stats():
    """The jnp per-leaf mirror reduces the SAME draws as the slab
    epilogue (shared PRNG contract), so the statistics agree to f32
    summation order."""
    from repro.core.ota import _cms_slab_inputs
    from repro.core.channel import cms_transform
    cfg = OTAChannelConfig(alpha=1.4, xi_scale=0.2)
    params = _params(jax.random.key(8))
    spec = make_slab_spec(params)
    kx = jax.random.key(9)
    per_leaf = interference_log_moment_stats(kx, cfg, params)
    u, e = _cms_slab_inputs(kx, spec)
    slab = log_moment_stats(cfg.xi_scale * cms_transform(u, e, cfg.alpha))
    np.testing.assert_allclose(np.asarray(per_leaf), np.asarray(slab),
                               rtol=1e-5)
    assert float(per_leaf[0]) == spec.total


# ---------------------------------------------------------------------------
# Traced-alpha kernels.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["adagrad", "adam", "amsgrad", "yogi"])
def test_traced_alpha_matches_static_kernel(mode):
    """Promoting alpha to a runtime operand must not change the math:
    the traced launch reproduces the baked-constant launch at the same
    numeric alpha."""
    from repro.kernels.adaptive_update import adaptive_update_slab
    d = 700
    g = jax.random.normal(jax.random.key(10), (d,))
    dl = jax.random.normal(jax.random.key(11), (d,))
    nu = jnp.abs(jax.random.normal(jax.random.key(12), (d,)))
    w = jax.random.normal(jax.random.key(13), (d,))
    kw = dict(lr=0.05, beta1=0.9, beta2=0.3, eps=1e-8, mode=mode)
    if mode == "amsgrad":
        kw["nu_max"] = nu * 1.5
    static = adaptive_update_slab(g, dl, nu, w, alpha=1.37, **kw)
    traced = adaptive_update_slab(g, dl, nu, w,
                                  alpha=jnp.asarray(1.37, jnp.float32), **kw)
    # also under jit, where the traced alpha is a real tracer
    jitted = jax.jit(lambda a: adaptive_update_slab(g, dl, nu, w, alpha=a,
                                                    **kw))(
        jnp.asarray(1.37, jnp.float32))
    for a, b, c in zip(static, traced, jitted):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(b), np.asarray(c),
                                   rtol=1e-6, atol=1e-7)


def test_traced_alpha_jnp_optimizer_override():
    """The per-leaf update's alpha= override matches rebuilding the
    optimizer with that static alpha."""
    params = _params(jax.random.key(14))
    g = jax.tree.map(lambda p: jax.random.normal(jax.random.key(15),
                                                 p.shape), params)
    base = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5,
                          beta2=0.3)
    pinned = make_server_optimizer(
        AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.31, beta2=0.3))
    overridden = make_server_optimizer(base)
    from repro.core import init_server
    s0 = init_server(params, base)
    p_a, s_a = pinned.update(g, s0, params)
    p_b, s_b = overridden.update(g, s0, params,
                                 alpha=jnp.asarray(1.31, jnp.float32))
    # python-float vs f32-scalar alpha round 1/alpha differently by an
    # ulp, which the fractional powers amplify — semantic, not bitwise,
    # agreement is the contract here
    for x, y in zip(jax.tree.leaves((p_a, s_a.nu)),
                    jax.tree.leaves((p_b, s_b.nu))):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=2e-4,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# The closed loop, end to end.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("true_alpha", [1.2, 1.5, 1.8])
def test_alpha_hat_converges_on_jnp_and_pallas(true_alpha):
    """ACCEPTANCE: RoundMetrics.alpha_hat within +-0.1 of the true
    channel tail index within 50 rounds, jnp and pallas engines."""
    params = _params(jax.random.key(0))
    ch = OTAChannelConfig(alpha=true_alpha, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha="auto",
                        beta2=0.3)
    fl = FLConfig(n_clients=4)
    finals = {}
    for backend in ("jnp", "pallas"):
        st, ms = _run_tracked(backend, params, ch, ad, fl, rounds=50)
        a_hat = float(ms.alpha_hat[-1])
        assert abs(a_hat - true_alpha) < 0.1, (backend, a_hat, true_alpha)
        assert float(st.alpha_hat) == a_hat   # resident == reported
        finals[backend] = a_hat
    np.testing.assert_allclose(finals["jnp"], finals["pallas"], rtol=1e-4)


def test_tracked_sharded_parity_single_shard_mesh():
    """pallas_sharded tracks identically (1e-5 vs the tracked jnp
    oracle) on the in-process (1,)-mesh; multi-device meshes run in the
    shard_check acceptance (--track-alpha, see CI)."""
    params = _params(jax.random.key(1))
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha="auto",
                        beta2=0.3)
    fl = FLConfig(n_clients=4)
    st_j, ms_j = _run_tracked("jnp", params, ch, ad, fl, rounds=5)
    st_s, ms_s = _run_tracked("pallas_sharded", params, ch, ad, fl,
                              rounds=5, mesh=make_auto_mesh((1,), ("data",)))
    np.testing.assert_allclose(float(st_j.alpha_hat), float(st_s.alpha_hat),
                               rtol=1e-5)
    p_j, _ = unpack_train_state(ad, st_j)
    p_s, _ = unpack_train_state(ad, st_s)
    for x, y in zip(jax.tree.leaves(p_j), jax.tree.leaves(p_s)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5,
                                   atol=1e-5)
    np.testing.assert_allclose(np.asarray(ms_j.alpha_hat),
                               np.asarray(ms_s.alpha_hat), rtol=1e-5)


def test_tracking_works_on_int8_uplink():
    """The receive-kernel epilogue serves the quantized MAC too: the
    estimator sees the same interference (injected post-dequantize)."""
    params = _params(jax.random.key(2))
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          uplink=UplinkConfig(mode="int8"))
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha="auto",
                        beta2=0.3)
    fl = FLConfig(n_clients=4)
    st, ms = _run_tracked("pallas", params, ch, ad, fl, rounds=20)
    assert abs(float(ms.alpha_hat[-1]) - 1.5) < 0.15


def test_tracking_without_interference_holds_sentinel():
    """No interference -> nothing to estimate: alpha_hat stays at the
    unseeded sentinel and the update falls back to the Gaussian
    endpoint (alpha = 2) instead of dividing by a nonsense root."""
    params = _params(jax.random.key(4))
    ch = OTAChannelConfig(alpha=1.5, interference=False)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha="auto",
                        beta2=0.3)
    fl = FLConfig(n_clients=2)
    for backend in ("jnp", "pallas"):
        st, ms = _run_tracked(backend, params, ch, ad, fl, rounds=3)
        assert float(st.alpha_hat) == 0.0
        assert float(ms.alpha_hat[-1]) == 0.0
        assert np.isfinite(float(ms.loss[-1]))


def test_static_alpha_reports_config_value():
    params = _params(jax.random.key(5))
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.5, beta2=0.3)
    st, ms = _run_tracked("pallas", params, ch, ad, FLConfig(n_clients=2),
                          rounds=2)
    assert np.all(np.asarray(ms.alpha_hat) == 1.5)
    assert float(st.alpha_hat) == 0.0   # tracker never ran


# ---------------------------------------------------------------------------
# Guard rails.
# ---------------------------------------------------------------------------

def test_pytree_api_refuses_auto():
    ch, fl = OTAChannelConfig(), FLConfig(n_clients=2)
    ad = AdaptiveConfig(optimizer="adam_ota", alpha="auto")
    with pytest.raises(ValueError, match="resident"):
        make_round_step(_loss_fn, ch, ad, fl, backend="jnp")
    from repro.core.shard import shard_round_step
    with pytest.raises(ValueError, match="resident"):
        shard_round_step(_loss_fn, ch, ad, fl,
                         make_auto_mesh((1,), ("data",)))


def test_config_validates_alpha_strings():
    with pytest.raises(ValueError, match="auto"):
        AdaptiveConfig(alpha="online")
    with pytest.raises(ValueError, match="alpha_ema"):
        AdaptiveConfig(alpha="auto", alpha_ema=0.0)
    assert AdaptiveConfig(alpha="auto").track_alpha
    assert not AdaptiveConfig(alpha=1.5).track_alpha


def test_update_without_tracked_alpha_raises():
    """An "auto" config whose update never received the tracked scalar
    must fail loudly, not silently use a stale float."""
    params = _params(jax.random.key(6))
    ad = AdaptiveConfig(optimizer="adam_ota", alpha="auto")
    opt = make_server_optimizer(ad)
    from repro.core import init_server
    g = jax.tree.map(jnp.zeros_like, params)
    with pytest.raises(ValueError, match="threaded"):
        opt.update(g, init_server(params, ad), params)


# ---------------------------------------------------------------------------
# Checkpoint / resume of the tracker (satellite).
# ---------------------------------------------------------------------------

def test_tracked_checkpoint_resume_is_bitwise(tmp_path):
    """save -> load -> continue under --track-alpha semantics: the
    resumed trajectory (including alpha_hat) is bitwise-identical to the
    uninterrupted one, alpha_hat survives the slab-state fingerprint
    check, and layout drift is still refused."""
    params = _params(jax.random.key(7))
    n = 2
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha="auto",
                        beta2=0.3)
    fl = FLConfig(n_clients=n)
    run = make_slab_round_runner(_loss_fn, ch, ad, fl, backend="pallas")
    keys = jnp.stack([jax.random.fold_in(jax.random.key(5), t)
                      for t in range(4)])
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(4), (4, n) + p.shape),
        params)

    st_full, _ = run(init_train_state(ad, params), keys, batches)

    st_half, _ = run(init_train_state(ad, params), keys[:2],
                     jax.tree.map(lambda x: x[:2], batches))
    assert float(st_half.alpha_hat) > 0.0   # the tracker is seeded
    path = os.path.join(tmp_path, "round_2.npz")
    ckpt.save_slab_state(path, st_half)
    st_loaded, _ = ckpt.load_slab_state(path, st_half.spec)
    np.testing.assert_array_equal(np.asarray(st_loaded.alpha_hat),
                                  np.asarray(st_half.alpha_hat))
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend="pallas")
    st = st_loaded
    for t in (2, 3):
        st, _ = step(st, keys[t], jax.tree.map(lambda x: x[t], batches))
    np.testing.assert_array_equal(np.asarray(st.alpha_hat),
                                  np.asarray(st_full.alpha_hat))
    np.testing.assert_array_equal(np.asarray(st.w), np.asarray(st_full.w))
    for a, b in zip(st.opt, st_full.opt):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # layout drift is still refused with the tracker state present
    with pytest.raises(ValueError, match="layout mismatch"):
        ckpt.load_slab_state(path, make_slab_spec(params, shards=4))


def test_train_cli_track_alpha_resume_is_bitwise(tmp_path):
    """launch.train --track-alpha --resume: interrupted + resumed equals
    uninterrupted bitwise across processes (the checkpointed alpha_hat
    seeds the resumed EMA exactly)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    base = ["--preset", "tiny", "--rounds", "4", "--clients", "2",
            "--batch", "1", "--seq", "16", "--seed", "3", "--track-alpha",
            "--log-every", "1000", "--scan-rounds", "3", "--ckpt-every", "2"]

    def train(extra):
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", *base, *extra],
            capture_output=True, text=True, cwd=REPO_ROOT, env=env,
            timeout=600)
        assert res.returncode == 0, res.stdout + res.stderr
        return res.stdout

    full_dir, part_dir = str(tmp_path / "full"), str(tmp_path / "part")
    out_full = train(["--ckpt-dir", full_dir])
    assert "alpha_hat" in out_full
    train(["--ckpt-dir", part_dir, "--rounds", "2"])
    out = train(["--ckpt-dir", part_dir, "--resume"])
    assert "resumed from" in out and "at round 2" in out

    a = np.load(os.path.join(full_dir, "round_4.npz"))
    b = np.load(os.path.join(part_dir, "round_4.npz"))
    assert set(a.files) == set(b.files)
    assert "alpha_hat" in a.files
    assert float(a["alpha_hat"]) > 0.0
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k])
