"""Wireless-channel models for analog over-the-air (A-OTA) aggregation.

The paper (Sec. III/VI) models the uplink multiple-access channel as

    g_t = (1/N) * sum_n h_{n,t} * grad_n  +  xi_t                  (Eq. 7)

with i.i.d. channel fading ``h_{n,t}`` (Rayleigh in the experiments, mean
``mu_c``, variance ``sigma_c**2``) and i.i.d. symmetric alpha-stable
interference ``xi_t`` with tail index ``alpha`` in (1, 2] and scale
``xi_scale`` (0.1 in the paper's default setup).

Everything here is pure JAX and jit/pjit-safe (shape-static, key-driven).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class UplinkConfig:
    """Static configuration of the uplink payload format (the MAC wire).

    The uplink pipeline runs in five explicit stages — transmit power
    control -> quantize -> MAC superposition -> interference injection ->
    receiver dequantize/scale — and this config owns the *quantize /
    dequantize* stages:

    * ``mode == "f32"`` (default): the payload is the raw float32 faded
      partial sum — exactly today's analog-OTA behaviour, bit for bit.
      The quantize/dequantize stages are identity.
    * ``mode == "int8"``: each transmitter quantizes its faded partial
      sum to int8 with one float32 scale per ``block`` consecutive slab
      entries (symmetric, scale = blockwise max|x| / 127), so the MAC
      collective carries ~4x fewer bytes (d int8 + d/block f32 vs d
      f32). The receiver dequantizes before the interference is applied
      (the server's RF front end is analog either way).
    * ``mode == "sign"``: 1-bit signSGD payload — each transmitter sends
      ``sign(x)`` plus one f32 magnitude per ``block`` entries (the
      blockwise mean|x|, so the dequantized payload is ±scale). The
      receiver dequantize stage is unchanged and the quantizer is
      deterministic (canonical EF-signSGD) — the SR uniforms are still
      drawn so no other draw shifts, but the sign epilogue ignores
      them. ``sign_pack`` selects the WIRE representation (PR 8): the
      default ``"fold"`` ships a true 1-bit/coord uint32 bitplane (the
      quantizer folds zeros to +1 and gives all-zero blocks scale 0, so
      the zero tail still reconstructs exactly); ``"planes"`` ships two
      bitplanes (sign + nonzero mask, 2 bits/coord) and preserves
      {-1, 0, +1} payloads bitwise; ``"int8"`` is the PR 7 int8
      container (1 byte/coord on the wire — the parity oracle of the
      packed formats, and what the byte model previously over-counted
      by 8x).

    Sign (and aggressive int8) quantization is biased; pair it with
    ``error_feedback=True`` so each transmitter carries its residual
    ``e = x - dequant(quant(x))`` into the next round's payload
    (resident slab in ``SlabTrainState``), which restores adam_ota
    convergence (cf. arXiv 2107.12452).

    Attributes:
      mode: "f32" | "int8" | "sign".
      block: slab entries per quantization scale. Must equal the kernel
        lane width (128): the transmit kernel computes scales on lane-
        aligned tiles, and the shard-aligned slab padding guarantees
        every per-device slice is a whole number of blocks.
      stochastic_rounding: round ``x/scale`` stochastically
        (``floor(x/s + r)`` with r ~ U[0,1), unbiased — the draws come
        from the round key under the shared PRNG contract, so all
        backends make identical rounding decisions) instead of
        round-to-nearest. int8 only; the sign quantizer is
        deterministic.
      error_feedback: carry each transmitter's quantization residual
        across rounds and add it into the faded partial before the next
        quantize. Requires a quantized mode (f32 has no residual).
      sign_pack: wire representation of the sign payload ("fold" |
        "planes" | "int8", sign mode only — see the mode docs above).
      sr_inkernel: draw the int8 stochastic-rounding bits IN-KERNEL
        (``pltpu`` PRNG seeded from the same round-key derivation as
        ``sr_inputs``) on COMPILED pallas launches, instead of
        streaming the d host-drawn uniforms through HBM. Interpret-mode
        launches and the jnp backend always use the host-drawn path —
        it is the cross-backend parity oracle — so a config with this
        flag set runs everywhere; only compiled TPU rounds take the
        in-kernel branch (their rounding decisions then differ from the
        oracle's by at most one quantization step per entry, the
        documented quantized-uplink agreement contract). int8 +
        stochastic_rounding only.
    """

    mode: str = "f32"
    block: int = 128
    stochastic_rounding: bool = True
    error_feedback: bool = False
    sign_pack: str = "fold"
    sr_inkernel: bool = False

    def __post_init__(self):
        if self.mode not in ("f32", "int8", "sign"):
            raise ValueError(f'unknown uplink mode {self.mode!r}; '
                             'options: "f32", "int8", "sign"')
        if self.block != 128:
            raise ValueError(
                f"uplink block must be 128 (the kernel lane width the "
                f"transmit epilogue tiles scales over), got {self.block}")
        if self.error_feedback and self.mode == "f32":
            raise ValueError(
                'error_feedback requires a quantized uplink mode '
                '("int8" or "sign"); the f32 payload has no residual')
        if self.sign_pack not in ("fold", "planes", "int8"):
            raise ValueError(f'unknown sign_pack {self.sign_pack!r}; '
                             'options: "fold", "planes", "int8"')
        if self.sr_inkernel and not (self.mode == "int8"
                                     and self.stochastic_rounding):
            raise ValueError(
                "sr_inkernel needs the int8 uplink with "
                "stochastic_rounding=True (the sign quantizer is "
                "deterministic and f32 has no quantizer)")

    @property
    def quantized(self) -> bool:
        return self.mode != "f32"

    @property
    def packed_sign(self) -> Optional[str]:
        """The packed wire format of the sign payload ("fold" or
        "planes"), or None when the wire is the int8 container (any
        non-sign mode, or ``sign_pack="int8"``)."""
        if self.mode != "sign" or self.sign_pack == "int8":
            return None
        return self.sign_pack

    @property
    def zero_fold(self) -> bool:
        """True when the sign quantizer folds zeros (+1 signs, scale-0
        zero blocks) so the wire needs only the 1-bit sign plane."""
        return self.mode == "sign" and self.sign_pack == "fold"


# Domain separator folded into the round key for the stochastic-rounding
# uniforms — keeps them independent of the fading (kh) and interference
# (kx) sub-draws, so enabling the int8 uplink cannot perturb any f32
# draw (the f32 path stays bitwise-identical).
SR_FOLD = 0x5A8

# Domain separator for the DOWNLINK stochastic-rounding uniforms (the
# int8 model-broadcast quantizer). Separate from SR_FOLD for the same
# reason SR_FOLD is separate from the fading/interference sub-draws:
# enabling the quantized downlink must not perturb any uplink draw.
DL_FOLD = 0xD01

# Domain separator for the standalone fading draw of
# ``repro.core.ota.client_fading_weights`` (diagnostics/examples path;
# the round engines derive fading from the split round key instead).
# Every fold_in domain separator in the repo is mirrored in
# ``repro.analysis.fold_registry`` — the repro-lint fold rules fail on
# unregistered or colliding constants.
FADING_FOLD = 0x0FAD


def sr_inputs(key: jax.Array, shape: Tuple[int, ...],
              dtype=jnp.float32) -> jax.Array:
    """Uniform [0, 1) draws for the transmit quantizer's stochastic
    rounding, keyed off the ROUND key (``fold_in(key, SR_FOLD)``).

    This is the only random input of the quantize stage; like the CMS
    (u, e) draws it is produced upstream of the kernel, so the jnp and
    pallas backends consume literally identical rounding decisions.
    The sharded engine folds each device's linear shard index in on top
    (every device quantizes a different partial sum, so the draws are
    per-transmitter, like the fading)."""
    return jax.random.uniform(jax.random.fold_in(key, SR_FOLD), shape,
                              dtype=dtype)


def sr_kernel_seed(key: jax.Array, shard_index=0) -> jax.Array:
    """(2,) int32 seeds for the IN-KERNEL stochastic-rounding PRNG
    (``UplinkConfig.sr_inkernel``), derived from the same key chain as
    the host-drawn oracle: shard index folded in first, then
    ``SR_FOLD`` — exactly the ``uplink_sr_slab_inputs`` keying — so
    turning the in-kernel path on or off never perturbs any other
    sub-draw, and each shard's kernel seeds a distinct stream just as
    each shard slices distinct host draws. Row 0 seeds the noisy faded
    payload's rounding, row 1 the clean diagnostic payload's — the same
    row convention as the host draw.

    The in-kernel bits themselves are a DIFFERENT uniform stream from
    ``sr_inputs`` (pltpu's counter PRNG vs threefry); the agreement
    contract with the oracle is per-entry one-quantization-step, not
    bitwise (see kernels/ref.py)."""
    k = jax.random.fold_in(jax.random.fold_in(key, shard_index), SR_FOLD)
    return jax.random.randint(k, (2,), minval=jnp.iinfo(jnp.int32).min,
                              maxval=jnp.iinfo(jnp.int32).max,
                              dtype=jnp.int32)


@dataclasses.dataclass(frozen=True)
class OTAChannelConfig:
    """Static configuration of the simulated analog OTA channel.

    Attributes:
      alpha: tail index of the symmetric alpha-stable interference,
        in (1, 2]. ``alpha == 2`` is the Gaussian special case.
      xi_scale: scale (dispersion) of the interference distribution.
      fading: one of ``"rayleigh"``, ``"gaussian"``, ``"none"``.
        ``"none"`` gives the noiseless h == 1 channel.
      mu_c: mean of the fading distribution. Rayleigh fading is re-scaled
        so its mean equals ``mu_c`` (paper uses mu_c = 1).
      sigma_c: std-dev of the fading for the ``"gaussian"`` model. For
        Rayleigh the std-dev is determined by the mean
        (sigma = mu * sqrt(4/pi - 1)); this field is ignored then.
      interference: if False, xi_t == 0 (fading-only ablation).
      uplink: payload format of the MAC uplink (``UplinkConfig``; a bare
        mode string like ``"int8"`` is accepted and wrapped). Defaults
        to the f32 analog uplink — existing configs are untouched.
      downlink: payload format of the per-round model broadcast.
        ``"f32"`` (default) is the full-width broadcast, bit for bit.
        ``"int8"`` quantizes the broadcast weights with the same
        per-128-block symmetric scales as the int8 uplink (stochastic
        rounding keyed off the round key via ``DL_FOLD``), roughly
        quartering the remaining per-round traffic; every backend
        dequantizes identically so parity tiers are preserved.
      comm_buckets: number of slab buckets the sharded MAC exchange is
        split into (PR 9). With B > 1 each device's slab slice is
        divided into B lane-aligned sub-blocks and the MAC collective
        (``psum_scatter`` at f32, ``all_to_all`` for quantized
        payloads) is dispatched once per bucket, so bucket b's wire
        transfer can overlap bucket b+1's transmit/quantize epilogue
        (independent collectives expose pipeline parallelism to the
        runtime). ``1`` (default) takes the exact single-collective
        graph of PR 8 — bitwise. B > 1 reassociates the cross-device
        reduction per bucket, so it is held to the same loose
        tolerance tier as the quantized wire, not bitwise. Only the
        sharded engine consults this field; single-device rounds have
        no wire to bucket.
    """

    alpha: float = 1.5
    xi_scale: float = 0.1
    fading: str = "rayleigh"
    mu_c: float = 1.0
    sigma_c: float = 0.2
    interference: bool = True
    power_control: bool = False     # truncated channel inversion: with CSI
                                    # at the transmitter, clients pre-scale
                                    # by 1/h; deep fades (h < pc_threshold)
                                    # are truncated (client stays silent)
                                    # — the paper's related-work [33]-[35]
                                    # mechanism, as a channel option.
    pc_threshold: float = 0.2
    backend: str = "jnp"            # "jnp": per-leaf tree.map aggregation;
                                    # "pallas": one fused ota_channel_slab
                                    # launch over the whole model slab;
                                    # "pallas_sharded": per-device partial
                                    # MAC + cross-client psum over a mesh
                                    # (repro.core.shard) — outside
                                    # shard_map this behaves like "pallas".
    interpret: Optional[bool] = None  # Pallas interpret mode; None (the
                                      # default) auto-selects from the
                                      # platform — compiled on TPU,
                                      # interpreted everywhere else
                                      # (repro.kernels.interpret, env
                                      # override REPRO_PALLAS_INTERPRET).
    uplink: UplinkConfig = UplinkConfig()
    downlink: str = "f32"
    comm_buckets: int = 1

    def __post_init__(self):
        if not (1.0 < self.alpha <= 2.0):
            raise ValueError(f"tail index alpha must be in (1, 2], got {self.alpha}")
        if self.fading not in ("rayleigh", "gaussian", "none"):
            raise ValueError(f"unknown fading model: {self.fading}")
        if self.backend not in ("jnp", "pallas", "pallas_sharded"):
            raise ValueError(f"unknown channel backend: {self.backend}")
        if isinstance(self.uplink, str):
            object.__setattr__(self, "uplink", UplinkConfig(mode=self.uplink))
        if self.downlink not in ("f32", "int8"):
            raise ValueError(f'unknown downlink mode {self.downlink!r}; '
                             'options: "f32", "int8"')
        if self.comm_buckets < 1:
            raise ValueError(f"comm_buckets must be >= 1, got "
                             f"{self.comm_buckets}")

    @property
    def pc_transmit_prob(self) -> float:
        """P(h >= pc_threshold) under the raw fading law — the Bernoulli
        success probability of the truncated-channel-inversion effective
        fading (``power_control=True`` maps h to 1{h >= threshold})."""
        t = self.pc_threshold
        if self.fading == "none":
            return 1.0 if 1.0 >= t else 0.0
        if self.fading == "rayleigh":
            # Rayleigh(s) with mean mu_c has s = mu_c / sqrt(pi/2);
            # P(h >= t) = exp(-t^2 / (2 s^2)).
            s = self.mu_c / math.sqrt(math.pi / 2.0)
            return math.exp(-(t**2) / (2.0 * s**2))
        # Gaussian fading: P(h >= t) = Q((t - mu) / sigma).
        return 0.5 * math.erfc((t - self.mu_c) / (self.sigma_c * math.sqrt(2.0)))

    @property
    def fading_mean(self) -> float:
        """Mean of the EFFECTIVE fading the MAC applies. With power
        control the transmitter inverts its channel and deep fades stay
        silent, so the effective h is Bernoulli(p) with
        p = P(h >= pc_threshold): mean p — NOT mu_c (the old value, a
        bug: it ignored truncated inversion entirely)."""
        if self.power_control:
            return self.pc_transmit_prob
        return 1.0 if self.fading == "none" else self.mu_c

    @property
    def fading_var(self) -> float:
        """Variance of the effective fading; Bernoulli p(1-p) under
        power control (was the raw Rayleigh/Gaussian variance — wrong
        once truncated inversion rewrites h to 0/1)."""
        if self.power_control:
            p = self.pc_transmit_prob
            return p * (1.0 - p)
        if self.fading == "none":
            return 0.0
        if self.fading == "rayleigh":
            # Rayleigh(s): mean = s*sqrt(pi/2), var = (2 - pi/2) s^2.
            # With mean pinned to mu_c: var = mu_c^2 * (4/pi - 1).
            return self.mu_c**2 * (4.0 / math.pi - 1.0)
        return self.sigma_c**2


def sample_fading(key: jax.Array, cfg: OTAChannelConfig, shape: Tuple[int, ...],
                  dtype=jnp.float32) -> jax.Array:
    """Draw i.i.d. effective fading coefficients ``h`` (E[h] = mu_c when
    power control is off)."""
    if cfg.fading == "none":
        return jnp.ones(shape, dtype)
    if cfg.fading == "rayleigh":
        # Rayleigh with scale s has mean s*sqrt(pi/2); choose s so that the
        # mean equals mu_c, matching the paper's mu_c = 1 setup.
        s = cfg.mu_c / math.sqrt(math.pi / 2.0)
        u = jax.random.uniform(key, shape, dtype=dtype, minval=jnp.finfo(dtype).tiny)
        h = s * jnp.sqrt(-2.0 * jnp.log(u))
    else:
        # Truncated-free gaussian fading (can be negative; ablations).
        h = cfg.mu_c + cfg.sigma_c * jax.random.normal(key, shape, dtype)
    if cfg.power_control:
        # Transmitter inverts its known channel; below-threshold clients
        # stay silent (their gradient is lost this round).
        h = jnp.where(h >= cfg.pc_threshold, jnp.ones_like(h),
                      jnp.zeros_like(h))
    return h


# Angles are kept strictly inside (-pi/2, pi/2): at the endpoints f32
# cos() is a tiny NEGATIVE number, and the fractional powers of the CMS
# transform turn that into NaN (even at alpha == 2, where the transform
# should reduce to the perfectly finite Gaussian 2*sin(u)*sqrt(e)).
CMS_U_BOUND = math.pi / 2 - 1e-6
CMS_E_FLOOR = 1e-7


def cms_inputs(key: jax.Array, shape: Tuple[int, ...],
               dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Draw the (u, e) inputs of the CMS transform with the edge guards.

    u ~ Uniform(-pi/2, pi/2) bounded away from the endpoints, e ~ Exp(1)
    floored away from 0. These are the *only* random bits of the
    interference synthesis — the fused ``ota_channel_slab`` kernel
    consumes exactly these draws, so the jnp and pallas channel backends
    see identical noise.
    """
    ku, kw = jax.random.split(key)
    u = jax.random.uniform(ku, shape, dtype=dtype,
                           minval=-CMS_U_BOUND, maxval=CMS_U_BOUND)
    e = -jnp.log(jax.random.uniform(kw, shape, dtype=dtype,
                                    minval=jnp.finfo(dtype).tiny))
    return u, jnp.maximum(e, jnp.asarray(CMS_E_FLOOR, dtype))


def cms_transform(u: jax.Array, e: jax.Array, alpha) -> jax.Array:
    """Branch-free symmetric Chambers–Mallows–Stuck transform.

        X = sin(alpha u) / cos(u)^{1/alpha}
              * ( cos((1-alpha) u) / e )^{(1-alpha)/alpha}

    ``alpha`` may be a python float (static, e.g. inside a Pallas kernel
    body) or a traced scalar. Guards: u is clipped into the open interval
    the sampler guarantees and e is floored, so the transform is finite
    for every input — including endpoint angles and alpha == 2, where it
    reduces to the Gaussian special case 2*sin(u)*sqrt(e) ~ N(0, 2).
    """
    u = jnp.clip(u, -CMS_U_BOUND, CMS_U_BOUND)
    e = jnp.maximum(e, CMS_E_FLOOR)
    a = alpha
    return (jnp.sin(a * u) / jnp.cos(u) ** (1.0 / a)
            * (jnp.cos((1.0 - a) * u) / e) ** ((1.0 - a) / a))


def cms_transform_fast(u: jax.Array, e: jax.Array, alpha) -> jax.Array:
    """CMS transform with both generic powers fused into one exp.

        X = sin(alpha u) * exp( (1/alpha) * ( -log cos(u)
              + (1 - alpha) * log( cos((1-alpha) u) / e ) ) )

    Algebraically identical to :func:`cms_transform` but ~2x cheaper on
    backends where ``pow`` lowers to exp/log pairs: the two generic
    exponentiations collapse into two logs and a single exp. Results
    deviate from ``cms_transform`` by a few float32 ulps (~5e-7
    relative), so the overlap engine (``comm_buckets > 1``) uses it
    under its tolerance parity tier while the default engine keeps the
    bitwise-pinned form. Both cos arguments stay in (-pi/2, pi/2) after
    the clip, so the logs are finite for every guarded input.
    """
    u = jnp.clip(u, -CMS_U_BOUND, CMS_U_BOUND)
    e = jnp.maximum(e, CMS_E_FLOOR)
    a = alpha
    inner = -jnp.log(jnp.cos(u)) + (1.0 - a) * jnp.log(
        jnp.cos((1.0 - a) * u) / e)
    return jnp.sin(a * u) * jnp.exp(inner * (1.0 / a))


def sample_alpha_stable(key: jax.Array, alpha, shape: Tuple[int, ...],
                        scale=1.0, dtype=jnp.float32) -> jax.Array:
    """Symmetric alpha-stable sampler via the Chambers–Mallows–Stuck method.

    For S(alpha, beta=0, scale, 0): ``scale * cms_transform(u, e, alpha)``
    with (u, e) from ``cms_inputs``. ``alpha`` may be a traced scalar. At
    alpha == 2 this yields N(0, 2*scale^2) (standard stable
    parameterisation).
    """
    alpha = jnp.asarray(alpha, dtype)
    u, e = cms_inputs(key, shape, dtype)
    return jnp.asarray(scale, dtype) * cms_transform(u, e, alpha)


def sample_interference(key: jax.Array, cfg: OTAChannelConfig,
                        shape: Tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    """Interference vector xi_t with i.i.d. symmetric alpha-stable entries."""
    if not cfg.interference:
        return jnp.zeros(shape, dtype)
    return sample_alpha_stable(key, cfg.alpha, shape, cfg.xi_scale, dtype)


def interference_alpha_moment(cfg: OTAChannelConfig, d: int) -> float:
    """Upper-bound proxy ``G`` for E[||xi||_alpha^alpha] (Eq. 15).

    For a symmetric alpha-stable scalar X with scale c and tail index a, the
    fractional moment E|X|^p exists for p < a. The paper assumes the alpha-th
    moment is bounded by G; strictly E|X|^a diverges logarithmically, so for
    reporting the theory constant Upsilon we use the p = a * 0.95 moment as a
    finite stand-in and document the convention.
    """
    a, c = cfg.alpha, cfg.xi_scale
    p = 0.95 * a
    # E|X|^p for symmetric stable: c^p * 2^p * Gamma((1+p)/2) Gamma(1-p/a)
    #                              / (Gamma(1-p/2) * sqrt(pi))
    num = (2.0**p) * math.gamma((1 + p) / 2) * math.gamma(1 - p / a)
    den = math.gamma(1 - p / 2) * math.sqrt(math.pi)
    return d * (c**p) * num / den


def upsilon(cfg: OTAChannelConfig, d: int, n_clients: int, grad_bound: float) -> float:
    """The theory constant Upsilon of Theorem 1 (Eq. 22).

        Upsilon = 4G + d^{1-a/2} E[h^2]^{a/2} C^a / N^{a/2}

    ``E[h^2] = fading_mean^2 + fading_var`` is the second moment of the
    EFFECTIVE fading, so with ``power_control=True`` it is the Bernoulli
    transmit probability p (h is 0/1 after truncated inversion), not the
    raw Rayleigh moment.
    """
    a = cfg.alpha
    g = interference_alpha_moment(cfg, d) if cfg.interference else 0.0
    mu2 = cfg.fading_mean**2 + cfg.fading_var
    return 4.0 * g + d ** (1 - a / 2) * mu2 ** (a / 2) * grad_bound**a / n_clients ** (a / 2)
