"""Blocked causal GQA flash-attention Pallas kernel (TPU target).

Standard online-softmax tiling adapted to the TPU memory hierarchy:
the grid is (batch, q_head, q_block, kv_block) with the kv_block axis
innermost (sequential on TPU), so the running max / normaliser / output
accumulator live in VMEM scratch across kv steps — the classic
HBM-O(S) / VMEM-O(block^2) flash scheme. Q/K/V tiles are (bq, D) /
(bk, D) with D the head dim (padded to the 128 MXU lane); GQA is
expressed in the K/V BlockSpec index_map (query head h reads kv head
h // group) so no K/V duplication ever hits VMEM.

Supports causal masking and sliding-window masking (window w ->
kv blocks outside [q - w, q] are masked; the mask math is in-register).
Validated in interpret mode against ``ref.flash_attention_ref``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.interpret import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, bq: int, bk: int, n_kv_blocks: int, scale: float,
                  causal: bool, window: Optional[int], seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < seq_k
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H % K == 0.
    Returns (B, Sq, H, D) in q.dtype. ``interpret=None`` auto-selects
    Pallas interpret mode from the platform (compiled on TPU only)."""
    interpret = resolve_interpret(interpret)
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    assert h % kh == 0
    group = h // kh
    scale = 1.0 / math.sqrt(d)

    sq_pad = -(-sq // bq) * bq
    sk_pad = -(-sk // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0)))
    # (B, H, S, D) layout for clean per-(b, h) tiles.
    qp = qp.transpose(0, 2, 1, 3)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)

    n_q, n_k = sq_pad // bq, sk_pad // bk
    grid = (b, h, n_q, n_k)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv_blocks=n_k, scale=scale,
        causal=causal, window=window, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.transpose(0, 2, 1, 3)[:, :sq]
