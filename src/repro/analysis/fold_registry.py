"""The ledger of PRNG ``fold_in`` domain separators.

The engine keys every independent random draw of a round off ONE round
key via ``jax.random.fold_in(key, SEPARATOR)``. Two draws folding the
same separator would be perfectly correlated — the participation mask
reusing the stochastic-rounding stream, say — a bug that no numeric
test catches reliably (the corrupted streams are still individually
uniform). So the separators are ledgered here and machine-checked:

* **fold-collision** — two registered separators share a value;
* **fold-drift** — a ``*_FOLD`` constant defined in ``src/`` disagrees
  with (or is missing from) this registry;
* **fold-unregistered** — a literal ``>= MIN_SEPARATOR`` passed to
  ``fold_in`` that is not a registered value.

Literals below ``MIN_SEPARATOR`` are *index* folds (leaf index, shard
index, round number — dense small ints by construction) and exempt;
that is also why every separator is chosen ``>= 0x100``.

This module is deliberately standalone (values duplicated from their
defining modules as plain literals, no jax import) so the AST tier can
run without the engine's dependencies; fold-drift is exactly the check
that the duplicates never diverge.
"""

REGISTERED_FOLDS = {
    # repro/core/stream.py — the round participation mask draw.
    "PART_FOLD": 0xACCE,
    # repro/core/channel.py — uplink stochastic-rounding uniforms.
    "SR_FOLD": 0x5A8,
    # repro/core/ota.py keys downlink SR off repro/core/channel.py's
    # DL_FOLD; disjoint from SR_FOLD so uplink and downlink rounding
    # never correlate within a round.
    "DL_FOLD": 0xD01,
    # repro/core/channel.py — the standalone fading draw of
    # ``client_fading_weights`` (diagnostics/examples path).
    "FADING_FOLD": 0x0FAD,
}

# Smallest value treated as a domain separator; smaller fold_in
# literals are index folds and exempt from registration.
MIN_SEPARATOR = 0x100

assert all(v >= MIN_SEPARATOR for v in REGISTERED_FOLDS.values()), \
    "registered separators must be >= MIN_SEPARATOR"
