"""ADOTA server optimizers: exact formulas, classical reductions,
convergence behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.adaptive import (AdaptiveConfig, adagrad_ota, adam_ota,
                                 fedavg, fedavgm, make_server_optimizer,
                                 yogi_ota)


def _run_steps(opt, params, grads_seq):
    state = opt.init(params)
    for g in grads_seq:
        params, state = opt.update(g, state, params)
    return params, state


def test_adagrad_ota_matches_manual():
    cfg = AdaptiveConfig(optimizer="adagrad_ota", lr=0.1, beta1=0.5,
                         alpha=1.5, eps=1e-8)
    opt = adagrad_ota(cfg)
    w = {"x": jnp.array([1.0, -2.0])}
    gs = [{"x": jnp.array([0.3, -0.7])}, {"x": jnp.array([-0.1, 0.2])}]
    p, s = _run_steps(opt, w, gs)
    # manual
    delta = np.zeros(2)
    v = np.zeros(2)
    wm = np.array([1.0, -2.0])
    for g in [np.array([0.3, -0.7]), np.array([-0.1, 0.2])]:
        delta = 0.5 * delta + 0.5 * g
        v = v + np.abs(delta) ** 1.5
        wm = wm - 0.1 * delta / (v + 1e-8) ** (1 / 1.5)
    np.testing.assert_allclose(np.asarray(p["x"]), wm, rtol=1e-5)
    assert int(s.step) == 2


def test_adagrad_alpha2_reduces_to_classical():
    """Remark 8: alpha=2 retrieves standard AdaGrad (eps inside root)."""
    cfg = AdaptiveConfig(optimizer="adagrad_ota", lr=0.05, beta1=0.0,
                         alpha=2.0, eps=1e-10)
    opt = adagrad_ota(cfg)
    w = {"x": jnp.array([0.5])}
    gs = [{"x": jnp.array([g])} for g in [0.4, -0.3, 0.25]]
    p, _ = _run_steps(opt, w, gs)
    wm, acc = 0.5, 0.0
    for g in [0.4, -0.3, 0.25]:
        acc += g * g
        wm -= 0.05 * g / np.sqrt(acc + 1e-10)
    np.testing.assert_allclose(float(p["x"][0]), wm, rtol=1e-5)


def test_adam_ota_ema_formula():
    cfg = AdaptiveConfig(optimizer="adam_ota", lr=0.1, beta1=0.9, beta2=0.3,
                         alpha=1.5, eps=1e-8)
    opt = adam_ota(cfg)
    w = {"x": jnp.array([1.0])}
    gs = [{"x": jnp.array([0.5])}, {"x": jnp.array([-0.2])}]
    p, s = _run_steps(opt, w, gs)
    delta, v, wm = 0.0, 0.0, 1.0
    for g in [0.5, -0.2]:
        delta = 0.9 * delta + 0.1 * g
        v = 0.3 * v + 0.7 * abs(delta) ** 1.5
        wm -= 0.1 * delta / (v + 1e-8) ** (1 / 1.5)
    np.testing.assert_allclose(float(p["x"][0]), wm, rtol=1e-5)


def test_fedavgm_is_momentum_sgd():
    cfg = AdaptiveConfig(optimizer="fedavgm", lr=0.1, momentum=0.9)
    opt = fedavgm(cfg)
    w = {"x": jnp.array([1.0])}
    gs = [{"x": jnp.array([1.0])}, {"x": jnp.array([1.0])}]
    p, _ = _run_steps(opt, w, gs)
    # delta: 1.0 then 1.9; w: 1 - .1 - .19 = 0.71
    np.testing.assert_allclose(float(p["x"][0]), 0.71, rtol=1e-6)


@pytest.mark.parametrize("name", ["adagrad_ota", "adam_ota", "yogi_ota",
                                  "fedavgm", "fedavg"])
def test_all_optimizers_converge_quadratic(name):
    """Noiseless sanity: every server optimizer minimises a quadratic."""
    cfg = AdaptiveConfig(optimizer=name, lr=0.3 if "ota" in name else 0.05,
                         alpha=1.5, beta2=0.3)
    opt = make_server_optimizer(cfg)
    target = jnp.arange(4, dtype=jnp.float32)
    w = {"x": jnp.zeros(4)}
    state = opt.init(w)
    for _ in range(400):
        g = {"x": w["x"] - target}
        w, state = opt.update(g, state, w)
    # EMA-v optimizers with constant eta settle into a small ball around
    # the optimum; from ||w0 - target|| = sqrt(14) ~ 3.7, reaching <0.4 on
    # every coordinate is convergence.
    assert float(jnp.max(jnp.abs(w["x"] - target))) < 0.4


def test_adaptive_robust_to_impulse():
    """The alpha-root stepsize bounds the damage of one huge impulse; plain
    SGD at the same lr is thrown far away (the paper's core motivation)."""
    tgt = jnp.zeros(4)
    impulse = {"x": jnp.full(4, 1e4)}

    def run(name, lr):
        cfg = AdaptiveConfig(optimizer=name, lr=lr, alpha=1.5, beta2=0.3)
        opt = make_server_optimizer(cfg)
        w = {"x": jnp.ones(4)}
        s = opt.init(w)
        peak = 0.0
        for t in range(50):
            g = {"x": w["x"] - tgt}
            if t == 25:
                g = impulse
            w, s = opt.update(g, s, w)
            peak = max(peak, float(jnp.max(jnp.abs(w["x"]))))
        return peak

    # adaptive stepsize caps the excursion at ~lr per round; SGD's PEAK
    # excursion is lr * |impulse| in the impulse round.
    peak_adaptive = run("adam_ota", 0.3)
    peak_sgd = run("fedavg", 0.3)
    assert peak_adaptive < 10.0
    assert peak_sgd > 100.0
    assert peak_sgd > 20 * peak_adaptive


@settings(max_examples=25, deadline=None)
@given(alpha=st.floats(1.05, 2.0), g=st.floats(-5, 5),
       beta1=st.floats(0.0, 0.99))
def test_update_finite_and_descent_direction(alpha, g, beta1):
    """Property: one step from zero state moves opposite to g, finitely."""
    cfg = AdaptiveConfig(optimizer="adam_ota", lr=0.1, beta1=beta1,
                         beta2=0.3, alpha=alpha)
    opt = adam_ota(cfg)
    w = {"x": jnp.array([0.0])}
    s = opt.init(w)
    p, _ = opt.update({"x": jnp.array([g])}, s, w)
    val = float(p["x"][0])
    assert np.isfinite(val)
    if abs(g) > 1e-3:
        assert val * g <= 0.0   # moved against the gradient


def test_state_shapes_mirror_params():
    cfg = AdaptiveConfig(optimizer="adagrad_ota")
    opt = adagrad_ota(cfg)
    params = {"a": jnp.ones((3, 4), jnp.bfloat16), "b": jnp.ones(7)}
    s = opt.init(params)
    assert jax.tree.structure(s.delta) == jax.tree.structure(params)
    for d in jax.tree.leaves(s.delta):
        assert d.dtype == jnp.float32


def test_amsgrad_ota_monotone_denominator():
    """AMSGrad-OTA's vmax never decreases; after a huge impulse the
    stepsize stays damped (unlike Adam-OTA whose EMA forgets)."""
    from repro.core.adaptive import amsgrad_ota
    cfg = AdaptiveConfig(optimizer="amsgrad_ota", lr=0.1, beta2=0.3,
                         alpha=1.5)
    opt = amsgrad_ota(cfg)
    w = {"x": jnp.array([0.0])}
    s = opt.init(w)
    prev_vmax = 0.0
    for g in [0.1, 100.0, 0.1, 0.1]:
        w, s = opt.update({"x": jnp.array([g])}, s, w)
        vm = float(s.nu["vmax"]["x"][0])
        assert vm >= prev_vmax
        prev_vmax = vm
    assert np.isfinite(float(w["x"][0]))


def test_amsgrad_converges_quadratic():
    from repro.core.adaptive import make_server_optimizer
    cfg = AdaptiveConfig(optimizer="amsgrad_ota", lr=0.3, alpha=1.5,
                         beta2=0.3)
    opt = make_server_optimizer(cfg)
    target = jnp.arange(4, dtype=jnp.float32)
    w = {"x": jnp.zeros(4)}
    state = opt.init(w)
    for _ in range(400):
        w, state = opt.update({"x": w["x"] - target}, state, w)
    assert float(jnp.max(jnp.abs(w["x"] - target))) < 0.4
