"""StarCoder2-15B [arXiv:2402.19173]: 40L, d_model 6144, 48 heads (GQA
kv=4), d_ff 24576, vocab 49152; LayerNorm + GeLU FFN with biases, RoPE,
native sliding-window attention (w=4096) -> runs long_500k with its own
windowed ring cache."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab=49152, norm="layernorm", mlp="gelu", qkv_bias=True,
    rope_theta=100000.0, window=4096,
    notes="GQA kv=4, RoPE, sliding window 4096 [arXiv:2402.19173]",
)
