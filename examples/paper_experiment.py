"""Paper-style experiment driver: reproduce the Fig. 2 comparison and the
alpha sweep (Fig. 5) on the CPU-sized synthetic stand-ins, printing the
orderings the paper claims.

    PYTHONPATH=src python examples/paper_experiment.py [--rounds 80]
"""

import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import paper_figs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    args = ap.parse_args()
    paper_figs.ROUNDS = args.rounds

    print("=== Fig.2: ADOTA vs FedAvgM (logreg / EMNIST-like, Dir=0.1, a=1.5)")
    recs = paper_figs.fig2()
    for r in recs:
        print(f"  {r['optimizer']:12s} loss {r['final_loss']:.4f} "
              f"acc {r['accuracy']:.4f}")
    by = {r["optimizer"]: r for r in recs}
    assert by["adam_ota"]["accuracy"] >= by["fedavgm"]["accuracy"], \
        "paper claim violated: Adam-OTA should beat FedAvgM"

    print("=== Fig.5: tail-index sweep (AdaGrad-OTA)")
    recs = paper_figs.fig5()
    for r in recs:
        print(f"  alpha={r['alpha']:.1f} loss {r['final_loss']:.4f}")
    losses = [r["final_loss"] for r in recs]
    print("  (expected: loss decreases as alpha rises)",
          "OK" if losses[0] >= losses[-1] else "VIOLATED")


if __name__ == "__main__":
    main()
