"""Multi-round train-loop benchmark: slab-RESIDENT vs per-round pytree
loop (separate process on purpose — the sharded variants need forced
host devices, and jax locks the device count at first backend init; see
benchmarks/shard_bench.py).

Times R full ADOTA rounds through four loop structures:

* ``pallas / resident``   — ``make_slab_round_runner``: one
  ``jax.lax.scan`` over the ``SlabTrainState``; zero pack/unpack in
  steady state.
* ``pallas / perround``   — ``make_round_step`` Python loop: packs
  params + k optimizer slabs and unpacks them again EVERY round.
* ``pallas_sharded / resident`` — scan inside ``shard_map``; each
  device carries only its slab slices; collectives are one
  ``all_gather`` (model broadcast) + one ``psum_scatter`` (MAC) per
  round.
* ``pallas_sharded / perround`` — the pytree-per-round API (PR-2
  style): full psums + a full-model materialisation at every call
  boundary.

Wall time on this CPU container measures Pallas interpret mode (the
Python kernel loop), so the hardware-relevant columns are the derived
bytes models, per device and per round (ring-collective cost ~= payload
for reduce-scatter/all-gather/all-to-all, 2x for all-reduce). The MAC
collective — the uplink — is broken out in its own
``uplink_bytes_per_round`` column, since it is the term the uplink
payload format (``--uplink``) scales:

    uplink f32     : reduce-scatter of [g, clean] = 2d f32 words
                     = 8d bytes
    uplink int8    : all-to-all of 2 int8 payload rows + 2 per-128-
                     block f32 scale rows = 2d + d/16 bytes  (~3.9x
                     fewer than f32)
    uplink sign    : 2 bit-packed sign rows + 2 scale rows
                     = 2(d/8) + d/16 bytes  (~25x fewer than f32).
                     Since PR 8 the exchange PHYSICALLY ships these
                     uint32 bitplane words (--sign-pack fold, the
                     default); the sign_c8 cell keeps the PR 7 int8
                     container (2d + d/16 bytes) timed next to it, and
                     every record carries a MEASURED
                     uplink_wire_bytes_measured column asserted equal
                     to the model

The model broadcast — the downlink — gets the same treatment in
``downlink_bytes_per_round`` (PR 7). It is the server->client payload
per round, so it is reported for every mesh (on the sharded mesh it is
also the all_gather word count, since each engine quantizes its own
slice before gathering):

    downlink f32   : d f32 words = 4d bytes
    downlink int8  : d int8 codewords + d/128 f32 scales
                     = d + d/32 bytes  (~3.9x fewer than f32)

    comms resident : downlink gather + uplink
    comms perround : resident + 4(k+1)d boundary materialisation of
                     the k state slabs + params the pytree API gathers
                     every call
    hbm   resident : 4x [MAC (N/P + 2)d + fused update 7(d/P) (4 reads
                     + 3 writes, same model as shard_bench) + d
                     unflatten]
    hbm   perround : resident + 8(k+1)d boundary pack/unpack traffic

So for adam the shipped per-round pytree loop moves 2x the collective
bytes of the resident loop, and the int8 uplink cuts the resident
loop's MAC bytes ~3.9x (total collective bytes ~2.0x, the f32 model
broadcast being the survivor). (The PR-2 implementation PR 3 deleted —
full psum of [g, clean] plus a masked-psum regather of every row —
moved 2*2d + 2(k+1)d = 10d f32 words, 3.3x the resident loop; it no
longer exists to time.)

``--stream-clients`` adds the STREAMED-client-axis records (PR 6): the
round scans the population in ``--stream-chunk`` rows through the
accumulating transmit kernel, client batches are synthesized in-graph
(``batch_gen``) so nothing of size N is ever materialised, and the
headline column is ``clients_per_sec`` — the axis the resident loop
cannot scale (a million f32 clients at d=4096 would need a 16 TB
gradient stack; the streamed round peaks at chunk * d).

    PYTHONPATH=src python -m benchmarks.train_loop_bench --sizes 16384
    PYTHONPATH=src python -m benchmarks.train_loop_bench --stream-only \
        --stream-clients 1000 100000 1000000
"""

import sys

from repro.launch.hostdev import (force_host_devices, mesh_device_count,
                                  positive_int)

force_host_devices(mesh_device_count(sys.argv, "--mesh"))

import argparse
import json
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _loop_bytes(n_params: int, n_clients: int, n_dev: int, state_rows: int,
                resident: bool, uplink: str = "f32",
                downlink: str = "f32", sign_pack: str = "fold") -> dict:
    """Per-device, per-round traffic models (bytes).

    ``state_rows`` is the optimizer-slab count (2 for adam: delta, nu);
    the per-round pytree API regathers/repacks those plus the params row.
    ``uplink`` sets the MAC wire format: the f32 reduce-scatter carries
    2 rows of d 4-byte words, the int8 all-to-all carries 2 rows of d
    1-byte codewords + 2 rows of d/128 4-byte scales, and sign ships 2
    packed rows whose width ``sign_pack`` sets — d/8 bytes of sign bits
    ('fold', the PR 8 uint32 bitplane wire), 2d/8 with the separate
    nonzero-mask plane ('planes'), or d int8 codewords ('int8', the
    PR 7 byte-per-coord container the packed wire replaced).
    ``downlink`` sets the model-broadcast format; its payload is
    reported for every mesh (it is the server->client wire even when
    there is no device collective to time). Since PR 8 the sign models
    are what the exchange PHYSICALLY ships (``pack_sign_slab`` words);
    ``_measured_uplink_bytes`` counts the actual wire arrays so the
    records carry model and measurement side by side.
    """
    d, p = n_params, n_dev
    boundary_rows = state_rows + 1
    if p == 1:
        mac = 0
    elif uplink == "int8":
        mac = 2 * d + 2 * (d // 128) * 4
    elif uplink == "sign":
        payload = {"fold": d // 8, "planes": 2 * (d // 8),
                   "int8": d}[sign_pack]
        mac = 2 * payload + 2 * (d // 128) * 4
    else:
        mac = 2 * d * 4
    dl = (d + (d // 128) * 4) if downlink == "int8" else 4 * d
    gather = dl if p > 1 else 0
    if resident:
        comms = gather + mac
        hbm = 4 * (d * (n_clients // p + 2) + 7 * d // p + d)
    else:
        comms = gather + mac + (4 * boundary_rows * d if p > 1 else 0)
        hbm = 4 * (d * (n_clients // p + 2) + 7 * d // p + d
                   + 2 * boundary_rows * d)
    return {"comms_bytes_per_round": comms,
            "uplink_bytes_per_round": mac,
            "downlink_bytes_per_round": dl,
            "hbm_bytes_est": hbm}


def _interpret_meta() -> dict:
    """Kernel-mode provenance stamped into every record: the resolved
    interpret bool (what the Pallas launches in this process actually
    did) plus the raw REPRO_PALLAS_INTERPRET env var. Interpret-mode
    wall clock is a Python-loop artifact, so a record is only
    roofline-gradable when this says compiled."""
    from repro.kernels.interpret import INTERPRET_ENV, resolve_interpret
    return {"resolved": resolve_interpret(None),
            "env": os.environ.get(INTERPRET_ENV)}


def _measured_uplink_bytes(n_params: int, n_dev: int, uplink: str,
                           sign_pack: str = "fold") -> int:
    """MEASURED per-device uplink wire bytes: build the actual arrays
    one device contributes to the MAC exchange (2 payload rows — noisy
    + clean — and their per-128-block scale rows, through the same
    ``pack_sign_slab`` epilogue the engine runs) and count ``nbytes``.
    This is the check that ``uplink_bytes_per_round`` (the model above)
    claims what the wire carries — the two are asserted equal, so a
    format change that forgets one side fails the bench, not CI months
    later."""
    import jax.numpy as jnp
    from repro.kernels.ota_channel import pack_sign_slab

    d = n_params
    if n_dev == 1:
        return 0
    scales = jnp.zeros((2, d // 128), jnp.float32)
    if uplink == "f32":
        return 2 * jnp.zeros((d,), jnp.float32).nbytes
    payload = jnp.zeros((2, d), jnp.int8)
    if uplink == "sign" and sign_pack != "int8":
        payload = pack_sign_slab(payload, planes=(sign_pack == "planes"))
    return payload.nbytes + scales.nbytes


def bench_train_loop(n_params: int, n_clients: int = 8, rounds: int = 8,
                     mesh_shape=(2,), iters: int = 2,
                     comm_buckets: int = 4) -> list:
    import jax
    import jax.numpy as jnp
    from benchmarks.kernel_bench import _round_step_case
    from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                            UplinkConfig, init_server, init_train_state,
                            make_round_step, make_slab_round_runner)
    from repro.launch.mesh import make_client_mesh

    params, loss_fn, batches = _round_step_case(n_params, n_clients)
    # (uplink, downlink, sign_pack) wire-format cells timed by the
    # resident loop; the quantized uplinks carry the PR-7 error-feedback
    # slab so the timing includes the residual read-modify-write. The
    # sign cells default to the PR 8 bit-packed 'fold' wire; the
    # trailing 'int8'-container cell keeps the PR 7 byte-per-coord wire
    # measurable next to it (the ~8x payload cut the packing buys).
    wire_cells = (("f32", "f32", "fold"), ("int8", "f32", "fold"),
                  ("sign", "f32", "fold"), ("sign", "int8", "fold"),
                  ("sign", "f32", "int8"))
    channels = {(u, dl, sp): OTAChannelConfig(
                    alpha=1.5, xi_scale=0.1, downlink=dl,
                    uplink=UplinkConfig(mode=u, sign_pack=sp,
                                        error_feedback=(u != "f32")))
                for u, dl, sp in wire_cells}
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.02, alpha=1.5)
    fl = FLConfig(n_clients=n_clients)
    k_rows = 2   # adam: delta, nu
    keys = jnp.stack([jax.random.fold_in(jax.random.key(2), t)
                      for t in range(rounds)])
    stacked = jax.tree.map(lambda b: jnp.stack([b] * rounds), batches)
    n_dev = 1
    for s in mesh_shape:
        n_dev *= s
    records = []

    def record(name, backend, variant, us_total, p, uplink,
               downlink="f32", sign_pack="fold"):
        us_round = us_total / rounds
        byt = _loop_bytes(n_params, n_clients, p, k_rows,
                          variant == "resident", uplink, downlink,
                          sign_pack)
        wire = _measured_uplink_bytes(n_params, p, uplink, sign_pack)
        if wire != byt["uplink_bytes_per_round"]:
            raise AssertionError(
                f"{name}: uplink byte model claims "
                f"{byt['uplink_bytes_per_round']} B/round but the wire "
                f"arrays measure {wire} B — model and exchange drifted")
        records.append(dict(
            name=name, backend=backend, variant=variant, uplink=uplink,
            downlink=downlink, sign_pack=sign_pack,
            interpret=_interpret_meta(),
            n_params=n_params, n_clients=n_clients, rounds=rounds,
            mesh="x".join(str(s) for s in mesh_shape) if p > 1 else "1",
            us_per_round=us_round, us_per_call=us_round,
            rounds_per_sec=1e6 / us_round,
            uplink_wire_bytes_measured=wire, **byt,
            derived=(f"rounds_per_sec={1e6 / us_round:.2f};"
                     f"comms_bytes={byt['comms_bytes_per_round']};"
                     f"uplink_bytes={byt['uplink_bytes_per_round']};"
                     f"downlink_bytes={byt['downlink_bytes_per_round']};"
                     f"hbm_bytes={byt['hbm_bytes_est']}")))

    def timeit(fn):
        jax.block_until_ready(fn())          # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6

    for backend, mesh, p in (("pallas", None, 1),
                             ("pallas_sharded", make_client_mesh(mesh_shape),
                              n_dev)):
        # resident: R rounds, one scanned dispatch, state stays slabs;
        # timed per wire-format cell (int8/sign show the MAC-byte cut,
        # the sign+dl8 cell adds the quantized model broadcast, the
        # sign_c8 cell times the unpacked PR 7 container). NOTE: the
        # benches replay every cell from the same st0, so the runners
        # must NOT donate (donation would invalidate st0 after the
        # first call) — donate=False is the make_slab_round_runner
        # default.
        for uplink, downlink, sign_pack in wire_cells:
            ch = channels[(uplink, downlink, sign_pack)]
            run = make_slab_round_runner(loss_fn, ch, ad, fl,
                                         backend=backend, mesh=mesh)
            st0 = init_train_state(ad, params, shards=p,
                                   error_feedback=ch.uplink.error_feedback)
            us = timeit(lambda: run(st0, keys, stacked))
            suffix = "" if uplink == "f32" else f"_{uplink}"
            if uplink == "sign" and sign_pack == "int8":
                suffix += "_c8"
            if downlink != "f32":
                suffix += "_dl8"
            record(f"train_loop_{backend}_resident{suffix}_{n_params}",
                   backend, "resident", us, p, uplink, downlink,
                   sign_pack)

        if backend == "pallas_sharded" and comm_buckets > 1:
            # Overlap engine (PR 9): the f32 resident cell again with
            # the MAC collective split into comm_buckets bucketed
            # scatters interleaved with the per-bucket GEMM epilogue +
            # fast-exp CMS transform, fused metric psum, prefetched
            # downlink gather. Same wire bytes per round; compare its
            # rounds_per_sec against the adjacent plain resident record.
            ch = OTAChannelConfig(
                alpha=1.5, xi_scale=0.1,
                uplink=UplinkConfig(mode="f32"),
                comm_buckets=comm_buckets)
            run = make_slab_round_runner(loss_fn, ch, ad, fl,
                                         backend=backend, mesh=mesh)
            st0 = init_train_state(ad, params, shards=p)
            us = timeit(lambda: run(st0, keys, stacked))
            record(f"train_loop_{backend}_resident_cb{comm_buckets}"
                   f"_{n_params}", backend, "resident", us, p, "f32")
            records[-1]["comm_buckets"] = comm_buckets
            records[-1]["derived"] += f";comm_buckets={comm_buckets}"

        # per-round pytree API: pack/convert at every round boundary
        # (f32 only — the boundary-materialisation cost it isolates is
        # uplink-independent)
        rs = make_round_step(loss_fn, channels[("f32", "f32", "fold")], ad,
                             fl, backend=backend, mesh=mesh)
        s0 = init_server(params, ad)

        def loop(rs=rs, s0=s0):
            prm, s = params, s0
            for t in range(rounds):
                prm, s, m = rs(prm, s, keys[t], batches)
            return prm, s, m

        us = timeit(loop)
        record(f"train_loop_{backend}_perround_{n_params}", backend,
               "perround", us, p, "f32")
    return records


def bench_streamed_loop(n_params: int, n_clients: int, chunk: int = 2000,
                        sample_rate: float = 1.0, rounds: int = 2,
                        iters: int = 1, backend: str = "jnp",
                        double_buffer: bool = False) -> list:
    """Streamed-client-axis rounds at population sizes the resident loop
    cannot hold: batches are synthesized in-graph per chunk, so peak
    memory is O(chunk * d) no matter how large N gets."""
    import jax
    import jax.numpy as jnp
    from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                            init_train_state, make_slab_round_runner)

    chunk = min(chunk, n_clients)
    params = {"w": jax.random.normal(jax.random.key(0), (n_params,),
                                     jnp.float32)}

    def loss_fn(p, b):
        return jnp.mean((p["w"] - jnp.sin(b["phase"])) ** 2)

    def batch_gen(key, idx):
        # The client's "data" is a deterministic function of its index:
        # nothing of size N is ever materialised on the host.
        return {"phase": idx.astype(jnp.float32) * 1e-3}

    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1, backend=backend)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.02, alpha=1.5,
                        backend=backend)
    fl = FLConfig(n_clients=n_clients, client_chunk=chunk,
                  sample_rate=sample_rate, double_buffer=double_buffer)
    run = make_slab_round_runner(loss_fn, ch, ad, fl, backend=backend,
                                 batch_gen=batch_gen)
    st0 = init_train_state(ad, params)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(2), t)
                      for t in range(rounds)])

    jax.block_until_ready(run(st0, keys))            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = run(st0, keys)
    jax.block_until_ready(out)
    us_round = (time.perf_counter() - t0) / iters / rounds * 1e6
    cps = n_clients * 1e6 / us_round
    peak = 4 * chunk * n_params            # streamed gradient stack bytes
    if double_buffer:
        peak *= 2                          # two resident pipeline slots
    resident = 4 * n_clients * n_params    # what the resident stack needs
    suffix = "_dbuf" if double_buffer else ""
    return [dict(
        name=f"train_loop_streamed{suffix}_{n_clients}", backend=backend,
        variant="streamed", uplink="f32", interpret=_interpret_meta(),
        n_params=n_params, double_buffer=double_buffer,
        n_clients=n_clients, client_chunk=chunk, sample_rate=sample_rate,
        rounds=rounds, mesh="1", us_per_round=us_round, us_per_call=us_round,
        clients_per_sec=cps, rounds_per_sec=1e6 / us_round,
        stream_peak_bytes=peak, resident_equiv_bytes=resident,
        derived=(f"clients_per_sec={cps:.0f};chunk={chunk};"
                 f"double_buffer={double_buffer};"
                 f"stream_peak_bytes={peak};resident_equiv_bytes={resident}"))]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[1 << 14])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=positive_int, default=8)
    ap.add_argument("--mesh", default="2")
    ap.add_argument("--iters", type=positive_int, default=2)
    ap.add_argument("--host-devices", type=positive_int, default=None,
                    help="forced host device floor (consumed from raw "
                         "argv before the jax import at module top)")
    ap.add_argument("--stream-clients", type=int, nargs="*", default=[],
                    help="client populations for the streamed-axis "
                         "records (e.g. 1000 100000 1000000)")
    ap.add_argument("--stream-chunk", type=positive_int, default=2000)
    ap.add_argument("--stream-sample-rate", type=float, default=1.0)
    ap.add_argument("--stream-rounds", type=positive_int, default=2)
    ap.add_argument("--stream-size", type=int, default=4096,
                    help="model size d of the streamed records")
    ap.add_argument("--stream-backend", default="jnp",
                    choices=["jnp", "pallas"],
                    help="engine of the streamed records: jnp is the "
                         "realistic CPU wall-clock (pallas on this "
                         "container is interpret mode, i.e. a Python "
                         "kernel loop)")
    ap.add_argument("--stream-only", action="store_true",
                    help="skip the resident/perround records")
    ap.add_argument("--comm-buckets", type=positive_int, default=4,
                    help="bucket count of the overlapped sharded "
                         "resident record (1 skips the record)")
    ap.add_argument("--no-stream-dbuf", action="store_true",
                    help="skip the double-buffered twins of the "
                         "streamed records")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    records = []
    if not args.stream_only:
        for n in args.sizes:
            records.extend(bench_train_loop(n, args.clients, args.rounds,
                                            mesh_shape, args.iters,
                                            comm_buckets=args.comm_buckets))
    for n_clients in args.stream_clients:
        records.extend(bench_streamed_loop(
            args.stream_size, n_clients, args.stream_chunk,
            args.stream_sample_rate, args.stream_rounds,
            backend=args.stream_backend))
        if not args.no_stream_dbuf:
            records.extend(bench_streamed_loop(
                args.stream_size, n_clients, args.stream_chunk,
                args.stream_sample_rate, args.stream_rounds,
                backend=args.stream_backend, double_buffer=True))
    json.dump(records, sys.stdout)


if __name__ == "__main__":
    main()
