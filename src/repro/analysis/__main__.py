"""CLI: ``python -m repro.analysis [--jaxpr] [--baseline PATH] ...``.

Exit 0 when every finding is covered by the accepted baseline, 1 when
there are new findings (printed as ``file:line rule-id [severity]
message``), 2 on operator error. ``--write-baseline`` records the
current findings as accepted and exits 0.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.ast_rules import AST_RULES, analyze_repo
from repro.analysis.findings import (DEFAULT_BASELINE, load_baseline,
                                     new_findings, write_baseline)


def _default_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root three levels up.
    root = Path(__file__).resolve().parents[3]
    if (root / "src" / "repro").is_dir():
        return root
    cwd = Path.cwd()
    if (cwd / "src" / "repro").is_dir():
        return cwd
    raise SystemExit("repro-lint: cannot locate the repo root (no "
                     "src/repro next to this package or under the "
                     "current directory); pass --root")


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: machine-check the slab engine's "
                    "invariants (fold ledger, PRNG round discipline, "
                    "zero-tail restore, kernel/oracle mirror, import "
                    "hygiene; --jaxpr adds traced-contract checks).")
    parser.add_argument("--root", default=None,
                        help="repo root (default: autodetected)")
    parser.add_argument("--jaxpr", action="store_true",
                        help="also run the jaxpr tier (imports jax and "
                             "traces the round engine — slower)")
    parser.add_argument("--baseline", default=None,
                        help="accepted-findings file (default: "
                             f"<root>/{DEFAULT_BASELINE})")
    parser.add_argument("--write-baseline", action="store_true",
                        help="record current findings as accepted and "
                             "exit 0")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline; report every finding")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        # repro-lint: lazy-import (jaxpr_checks imports jax + the engine;
        # the AST tier must stay runnable without them)
        from repro.analysis.jaxpr_checks import JAXPR_RULES
        for tier, rules in (("ast", AST_RULES), ("jaxpr", JAXPR_RULES)):
            for rule, desc in rules.items():
                print(f"{rule:24} [{tier}]  {desc}")
        return 0

    root = Path(args.root).resolve() if args.root else _default_root()
    findings = analyze_repo(root)
    if args.jaxpr:
        # repro-lint: lazy-import (jaxpr tier is opt-in; keep the AST
        # tier jax-free)
        from repro.analysis.jaxpr_checks import run_jaxpr_checks
        findings += run_jaxpr_checks()
    findings.sort()

    baseline_path = (Path(args.baseline) if args.baseline
                     else root / DEFAULT_BASELINE)
    if args.write_baseline:
        write_baseline(str(baseline_path), findings)
        print(f"repro-lint: wrote {len(findings)} accepted finding(s) "
              f"to {baseline_path}")
        return 0

    try:
        baseline = ({} if args.no_baseline
                    else load_baseline(str(baseline_path)))
    except ValueError as exc:
        print(f"repro-lint: {exc}", file=sys.stderr)
        return 2
    fresh = new_findings(findings, baseline)
    for f in fresh:
        print(f.render())
    print(f"repro-lint: {len(fresh)} new finding(s), "
          f"{len(findings) - len(fresh)} baselined "
          f"({len(findings)} total)", file=sys.stderr)
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
