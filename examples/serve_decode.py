"""Batched serving example: prefill + autoregressive decode with the
per-family cache (ring KV for windowed archs, latent cache for MLA,
O(1) recurrent state for RWKV/hybrid).

    PYTHONPATH=src python examples/serve_decode.py -- --arch rwkv6-7b \
        --preset tiny --batch 4 --gen 16
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--" in sys.argv:
        sys.argv = [sys.argv[0]] + sys.argv[sys.argv.index("--") + 1:]
    elif len(sys.argv) == 1:
        sys.argv += ["--arch", "rwkv6-7b", "--preset", "tiny",
                     "--batch", "2", "--prompt-len", "32", "--gen", "8"]
    main()
