"""Overlapped round (PR 9): bucketed MAC collectives, double-buffered
client streaming, async slab checkpointing.

Parity tiers under test:

* ``comm_buckets=1`` and ``double_buffer=False`` ARE the default
  configs — the overlap knobs off must leave the existing engine's
  graph (bitwise, covered here as rerun determinism of the explicit-
  default config against the implicit one);
* ``comm_buckets > 1`` on the f32 uplink is a TOLERANCE tier: the
  bucketed psum_scatter reassociates the f32 MAC reduction and the
  interference draw crosses ``cms_transform_fast`` (fast-exp identity,
  ~5e-7 relative);
* ``comm_buckets > 1`` on QUANTIZED uplinks is BITWISE: bucketing a
  ppermute payload is a value-identical permutation of int8/packed
  words, so the quantized wire cannot drift;
* async checkpoints are BITWISE file-identical to blocking saves (the
  device->host snapshot is synchronous; only the npz encode + rename
  run behind the loop), and a resume from an async file is bitwise.

The in-process tests run on the (1,)-mesh (the pytest process keeps
jax's real single-device view); the multi-device overlap acceptance
runs ``repro.launch.shard_check --comm-buckets 4`` in a subprocess that
forces 8 host devices, exactly like the PR 3 acceptance.
"""

import dataclasses
import hashlib
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint as ckpt
from repro.compat import make_auto_mesh
from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        UplinkConfig, init_train_state,
                        make_slab_round_runner, make_slab_round_step)
from repro.core.channel import CMS_U_BOUND, cms_transform, cms_transform_fast

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# 486 elements -> one 512-wide (4 LANE-block) shard on the (1,)-mesh,
# so comm_buckets in {1, 2, 4} is valid in-process and 3 is not, and
# the 26-element pad tail crosses the overlap interference path.
SHAPES = [(3, 45), (130,), (1,), (220,)]
N = 8


def _params(key=None):
    ks = jax.random.split(key or jax.random.key(0), len(SHAPES))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, SHAPES))}


def _batches(params, n=N, key=None):
    return jax.tree.map(
        lambda p: jax.random.normal(key or jax.random.key(3),
                                    (n,) + p.shape), params)


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def _configs(uplink="f32", comm_buckets=1, alpha=1.5, downlink="f32",
             error_feedback=False, sign_pack="fold", **fl_kw):
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1,
                          comm_buckets=comm_buckets, downlink=downlink,
                          uplink=UplinkConfig(mode=uplink,
                                              sign_pack=sign_pack,
                                              error_feedback=error_feedback))
    ad = AdaptiveConfig(optimizer=fl_kw.pop("optimizer", "adam_ota"),
                        lr=0.05, alpha=alpha, beta2=0.3)
    return ch, ad, FLConfig(n_clients=fl_kw.pop("n_clients", N), **fl_kw)


def _run_sharded(ch, ad, fl, rounds=3, params=None, batches=None):
    """Slab-resident pallas_sharded trajectory on the (1,)-mesh."""
    params = params or _params()
    batches = batches if batches is not None else _batches(params)
    mesh = make_auto_mesh((1,), ("data",))
    run = make_slab_round_runner(_loss_fn, ch, ad, fl,
                                 backend="pallas_sharded", mesh=mesh)
    st = init_train_state(ad, params, shards=1,
                          error_feedback=ch.uplink.error_feedback)
    keys = jnp.stack([jax.random.fold_in(jax.random.key(6), t)
                      for t in range(rounds)])
    st, ms = run(st, keys, jax.tree.map(
        lambda b: jnp.stack([b] * rounds), batches))
    return st, ms


def _state_arrays(st):
    arrs = [st.w, *st.opt, st.alpha_hat]
    if getattr(st, "ef", None) is not None:
        arrs.append(st.ef)
    return arrs


def _assert_state_close(st_a, st_b, tol):
    for a, b in zip(_state_arrays(st_a), _state_arrays(st_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)


def _assert_state_bitwise(st_a, st_b):
    for a, b in zip(_state_arrays(st_a), _state_arrays(st_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Tentpole (b): bucketed MAC collectives
# ---------------------------------------------------------------------------

def test_bucket_count_one_is_bitwise_default():
    """comm_buckets=1 keeps the existing single-collective graph: the
    explicit-default config must be bitwise equal to the implicit one
    (no overlap machinery may leak into the B=1 round)."""
    ch, ad, fl = _configs(comm_buckets=1)
    st_a, ms_a = _run_sharded(ch, ad, fl)
    st_b, ms_b = _run_sharded(dataclasses.replace(ch), ad, fl)
    _assert_state_bitwise(st_a, st_b)
    np.testing.assert_array_equal(np.asarray(ms_a.loss),
                                  np.asarray(ms_b.loss))


@pytest.mark.parametrize("optimizer,alpha", [("adam_ota", 1.5),
                                             ("fedavg", 1.5),
                                             ("adam_ota", "auto")])
def test_bucketed_engine_close_to_default(optimizer, alpha):
    """The overlapped round (B=4: bucketed psum_scatter, fused metrics
    psum, fast-exp CMS draw, prefetched broadcast) stays within the f32
    tolerance tier of the default engine, with and without the closed
    alpha loop."""
    ch1, ad, fl = _configs(optimizer=optimizer, alpha=alpha)
    ch4 = dataclasses.replace(ch1, comm_buckets=4)
    st_1, ms_1 = _run_sharded(ch1, ad, fl, rounds=4)
    st_4, ms_4 = _run_sharded(ch4, ad, fl, rounds=4)
    _assert_state_close(st_1, st_4, 1e-4)
    assert int(st_4.step) == 4
    np.testing.assert_allclose(np.asarray(ms_1.loss),
                               np.asarray(ms_4.loss), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ms_1.noisy_grad_norm),
                               np.asarray(ms_4.noisy_grad_norm),
                               rtol=1e-3, atol=1e-4)


def test_bucketed_dynamic_round_close():
    """The dynamic (streamed + sampled) round body buckets its stacked
    [partial, clean] scatter the same way: B=4 within tolerance of B=1
    with Bernoulli participation on."""
    ch1, ad, fl = _configs(client_chunk=2, sample_rate=0.8)
    ch4 = dataclasses.replace(ch1, comm_buckets=4)
    st_1, ms_1 = _run_sharded(ch1, ad, fl, rounds=4)
    st_4, ms_4 = _run_sharded(ch4, ad, fl, rounds=4)
    _assert_state_close(st_1, st_4, 1e-4)
    # the participation draw is keyed off the round key alone: B cannot
    # change WHO participates
    np.testing.assert_array_equal(np.asarray(ms_1.n_participants),
                                  np.asarray(ms_4.n_participants))


@pytest.mark.parametrize("uplink,downlink,ef", [("int8", "f32", False),
                                                ("sign", "int8", True)])
def test_bucketed_quantized_uplink_is_bitwise(uplink, downlink, ef):
    """Bucketing a quantized exchange is a value-identical permutation
    of the wire words (the quantize epilogue runs before the split), so
    B=4 must reproduce B=1 BITWISE — including the EF residual slab and
    the int8 downlink (whose SR draw is keyed per round, prefetch or
    not)."""
    ch1, ad, fl = _configs(uplink=uplink, downlink=downlink,
                           error_feedback=ef)
    ch4 = dataclasses.replace(ch1, comm_buckets=4)
    st_1, ms_1 = _run_sharded(ch1, ad, fl, rounds=3)
    st_4, ms_4 = _run_sharded(ch4, ad, fl, rounds=3)
    _assert_state_bitwise(st_1, st_4)
    np.testing.assert_array_equal(np.asarray(ms_1.loss),
                                  np.asarray(ms_4.loss))


def test_comm_buckets_validation():
    """B must divide the per-shard LANE-block count (4 here), and the
    config refuses non-positive counts outright."""
    with pytest.raises(ValueError, match="comm_buckets"):
        OTAChannelConfig(comm_buckets=0)
    ch, ad, fl = _configs(comm_buckets=3)
    with pytest.raises(ValueError, match="comm_buckets"):
        _run_sharded(ch, ad, fl, rounds=1)


# ---------------------------------------------------------------------------
# Tentpole (a): double-buffered client streaming
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_double_buffer_close_to_plain_stream(backend):
    """The two-slot scan changes only the fold order of the chunked
    client reduction: dbuf on vs off stays within the cross-engine
    tolerance on every backend."""
    ch, ad, fl_p = _configs(client_chunk=2)
    fl_d = dataclasses.replace(fl_p, double_buffer=True)
    step = make_slab_round_step(_loss_fn, ch, ad, fl_p, backend=backend)
    step_d = make_slab_round_step(_loss_fn, ch, ad, fl_d, backend=backend)
    params = _params()
    batches = _batches(params)
    st_p, st_d = (init_train_state(ad, params) for _ in range(2))
    for t in range(3):
        k = jax.random.fold_in(jax.random.key(6), t)
        st_p, m_p = step(st_p, k, batches)
        st_d, m_d = step_d(st_d, k, batches)
    _assert_state_close(st_p, st_d, 1e-4)
    np.testing.assert_allclose(float(m_p.loss), float(m_d.loss), rtol=1e-5)


def test_double_buffer_with_buckets_sharded():
    """Everything on at once — dbuf streaming + B=4 bucketed exchange +
    participation — vs the fully-default engine, tolerance tier."""
    ch1, ad, fl_p = _configs(client_chunk=2, sample_rate=0.8)
    ch4 = dataclasses.replace(ch1, comm_buckets=4)
    fl_d = dataclasses.replace(fl_p, double_buffer=True)
    st_p, ms_p = _run_sharded(ch1, ad, fl_p, rounds=4)
    st_d, ms_d = _run_sharded(ch4, ad, fl_d, rounds=4)
    _assert_state_close(st_p, st_d, 1e-4)
    np.testing.assert_array_equal(np.asarray(ms_p.n_participants),
                                  np.asarray(ms_d.n_participants))


def test_double_buffer_needs_client_chunk():
    with pytest.raises(ValueError, match="double_buffer"):
        FLConfig(n_clients=N, double_buffer=True)


# ---------------------------------------------------------------------------
# Tentpole (c): async slab checkpointing
# ---------------------------------------------------------------------------

def _advance(step, st, t0, rounds, batches):
    for t in range(t0, t0 + rounds):
        st, m = step(st, jax.random.fold_in(jax.random.key(6), t), batches)
    return st


def test_async_ckpt_file_bitwise_equals_blocking(tmp_path):
    """save_slab_state(blocking=False) must produce byte-identical
    files (same arrays, same deterministic zip) and round-trip extras."""
    ch, ad, fl = _configs()
    params = _params()
    st = init_train_state(ad, params)
    p_sync = str(tmp_path / "sync.npz")
    p_async = str(tmp_path / "async.npz")
    extra = {"key": np.arange(4, dtype=np.uint32)}
    ckpt.save_slab_state(p_sync, st, extra=extra)
    ckpt.save_slab_state(p_async, st, extra=extra, blocking=False)
    ckpt.wait_for_async_saves()
    sha = [hashlib.sha256(open(p, "rb").read()).hexdigest()
           for p in (p_sync, p_async)]
    assert sha[0] == sha[1]
    st2, extra2 = ckpt.load_slab_state(p_async, st.spec)
    _assert_state_bitwise(st, st2)
    np.testing.assert_array_equal(extra2["key"], extra["key"])


def test_async_ckpt_resume_is_bitwise(tmp_path):
    """A trajectory resumed from an async checkpoint must be bitwise
    equal to the uninterrupted one."""
    ch, ad, fl = _configs()
    params = _params()
    batches = _batches(params)
    step = make_slab_round_step(_loss_fn, ch, ad, fl, backend="pallas")
    st = _advance(step, init_train_state(ad, params), 0, 2, batches)
    path = str(tmp_path / "round_2.npz")
    ckpt.save_slab_state(path, st, blocking=False)
    st_a = _advance(step, st, 2, 2, batches)     # overlaps the write
    st_r, _ = ckpt.load_slab_state(path, st.spec)
    st_b = _advance(step, st_r, 2, 2, batches)
    _assert_state_bitwise(st_a, st_b)
    assert int(st_b.step) == 4


def test_async_ckpt_snapshot_precedes_donation(tmp_path):
    """The device->host snapshot is synchronous: deleting (donating)
    every device buffer right after the non-blocking call must not
    corrupt the file."""
    ch, ad, fl = _configs()
    st = init_train_state(ad, _params())
    want = [np.array(a) for a in _state_arrays(st)]
    path = str(tmp_path / "donated.npz")
    ckpt.save_slab_state(path, st, blocking=False)
    for arr in _state_arrays(st):
        arr.delete()                 # what a donating dispatch does
    st2, _ = ckpt.load_slab_state(path, st.spec)
    for a, b in zip(want, _state_arrays(st2)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_async_ckpt_write_errors_surface(tmp_path, monkeypatch):
    """A failed background write must raise at the next join — a
    crashed async save cannot pass silently."""
    ch, ad, fl = _configs()
    st = init_train_state(ad, _params())

    def boom(path, arrays):
        raise OSError("disk gone")

    monkeypatch.setattr(ckpt, "_atomic_savez", boom)
    ckpt.save_slab_state(str(tmp_path / "x.npz"), st, blocking=False)
    with pytest.raises(RuntimeError, match="async checkpoint"):
        ckpt.wait_for_async_saves()
    ckpt.wait_for_async_saves()      # error queue drained; clean again


# ---------------------------------------------------------------------------
# Satellites: dead-round aggregation, bench --compare
# ---------------------------------------------------------------------------

def test_dead_round_aggregator_spans():
    from repro.core.fl import _DeadRoundAggregator
    lines = []
    agg = _DeadRoundAggregator(lines.append)
    agg.flush()                      # nothing recorded -> no line
    assert lines == []
    for t in (3, 4, 5):
        agg.record(t)
    agg.flush()
    assert len(lines) == 1
    assert "rounds 4-6" in lines[0] and "3 dead round(s)" in lines[0]
    agg.record(9)
    agg.flush()
    agg.flush()                      # count reset: no duplicate line
    assert len(lines) == 2
    assert "round" in lines[1] and "1 dead round(s)" in lines[1]


def test_bench_delta_column():
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.run import _delta_column
    prev = {"meta": {"git_sha": "abcdef1234"},
            "records": [{"name": "r1", "rounds_per_sec": 10.0},
                        {"name": "r3", "clients_per_sec": 100.0}]}
    assert (_delta_column({"name": "r1", "rounds_per_sec": 12.0}, prev, True)
            == "delta_rounds_per_sec=+20.0%_vs_abcdef1")
    assert "-50.0%" in _delta_column(
        {"name": "r3", "clients_per_sec": 50.0}, prev, True)
    assert _delta_column({"name": "brand-new"}, prev, True) == "delta=new"
    assert (_delta_column({"name": "r1", "rounds_per_sec": 12.0}, prev, False)
            == "delta=incomparable(fingerprint-drift)")
    # headline metric changed since the previous artifact
    assert (_delta_column({"name": "r3", "rounds_per_sec": 5.0}, prev, True)
            == "delta=new-metric")


def test_cms_transform_fast_matches_reference():
    """The fast-exp CMS transform is an algebraic rewrite of the
    textbook one: tight relative agreement across the (u, e, alpha)
    domain, and exactly zero on the pad sentinel (u=0, e=1)."""
    u = jnp.linspace(-CMS_U_BOUND, CMS_U_BOUND, 513)
    e = jnp.logspace(-5, 1, 513)
    for alpha in (0.8, 1.2, 1.5, 1.9):
        ref = np.asarray(cms_transform(u, e, alpha))
        fast = np.asarray(cms_transform_fast(u, e, alpha))
        np.testing.assert_allclose(fast, ref, rtol=2e-5, atol=1e-6)
    assert float(cms_transform_fast(jnp.zeros(()), jnp.ones(()), 1.5)) == 0.0


# ---------------------------------------------------------------------------
# Multi-device acceptance (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

def test_overlap_multi_device_acceptance():
    """ACCEPTANCE: the overlapped round (--comm-buckets 4) holds parity
    with the default-engine references on meshes (2,) and (4,2) — 8
    forced host devices, real collectives — at the 1e-4 tolerance tier,
    with bitwise rerun determinism (checked inside shard_check)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.shard_check",
         "--comm-buckets", "4", "--meshes", "2", "4,2", "--rounds", "3",
         "--optimizers", "adam_ota", "fedavg"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PARITY OK" in out.stdout, out.stdout
