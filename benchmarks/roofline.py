"""Roofline analysis from the dry-run artifacts.

Reads results/dryrun/*.json (written by repro.launch.dryrun), derives the
three roofline terms per (arch x shape) on the single-pod mesh, and emits
the §Roofline markdown table.

    compute    = FLOPs_per_device / 197e12        (v5e bf16 peak)
    memory     = bytes_per_device / 819e9         (HBM bw)
    collective = collective_bytes_per_device / 4.9e10  (~ICI link bw)

FLOPs/bytes/collective-bytes come from the depth-CALIBRATED measurements
(XLA counts scan bodies once; dryrun extrapolates from unrolled depth-2/4
compiles — see launch/dryrun.py:calibrate).
"""

from __future__ import annotations

import glob
import json
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 4.9e10           # bytes/s per link (~50 GB/s)


def load_records(path_glob: str = "results/dryrun/*.json") -> List[Dict]:
    """Load dry-run records; when the same (arch, shape, mesh, knobs) was
    re-run (e.g. a fix re-measurement in a later file), the later OK
    record supersedes the earlier one."""
    recs = []
    for p in sorted(glob.glob(path_glob)):
        with open(p) as f:
            data = json.load(f)
        recs.extend(data if isinstance(data, list) else [data])
    by_key: Dict = {}
    for r in recs:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"),
               r.get("optimizer"), r.get("fsdp"), r.get("shard_cache_seq"),
               r.get("state_dtype"), json.dumps(r.get("overrides", {}),
                                                sort_keys=True))
        prev = by_key.get(key)
        if prev is None or (r.get("ok") and not prev.get("ok")):
            by_key[key] = r
    return list(by_key.values())


def terms(rec: Dict) -> Optional[Dict]:
    cal = rec.get("calibrated")
    if not rec.get("ok") or not cal:
        return None
    t_c = cal["flops"] / PEAK_FLOPS
    t_m = cal["bytes_accessed"] / HBM_BW
    t_x = cal["collective_bytes"] / ICI_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    # MODEL_FLOPS: 6·N·D training, 2·N·D forward (prefill), 2·N per token
    # (decode); N = active params.
    n_act = rec["n_active_params"]
    shape = rec["shape"]
    chips = 512 if rec["mesh"] == "multi" else 256
    from repro.launch.specs import INPUT_SHAPES
    sh = INPUT_SHAPES[shape]
    if sh["kind"] == "train":
        model_flops = 6 * n_act * sh["seq"] * sh["batch"]
    elif sh["kind"] == "prefill":
        model_flops = 2 * n_act * sh["seq"] * sh["batch"]
    else:
        model_flops = 2 * n_act * sh["batch"]          # one token per seq
    model_flops_dev = model_flops / chips
    useful = model_flops_dev / cal["flops"] if cal["flops"] else float("nan")
    return dict(
        arch=rec["arch"], shape=shape, mesh=rec["mesh"],
        compute_s=t_c, memory_s=t_m, collective_s=t_x, dominant=dominant,
        model_flops=model_flops, model_flops_per_device=model_flops_dev,
        hlo_flops_per_device=cal["flops"],
        useful_ratio=useful,
        collectives=cal["collectives"],
        memory_bytes=rec.get("memory", {}),
    )


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def markdown_table(recs: List[Dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful (6ND/HLO) |",
            "|---|---|---|---|---|---|---|"]
    for r in recs:
        t = terms(r)
        if t is None or t["mesh"] != mesh:
            continue
        rows.append(
            f"| {t['arch']} | {t['shape']} | {_fmt_s(t['compute_s'])} "
            f"| {_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} "
            f"| **{t['dominant']}** | {t['useful_ratio']:.2f} |")
    return "\n".join(rows)


def pick_hillclimb_targets(recs: List[Dict]) -> Dict[str, Dict]:
    """worst roofline fraction (compute / max term), most collective-bound,
    most representative of the paper's technique (train_4k — where the OTA
    gradient path and ADOTA update actually run)."""
    ts = [t for t in (terms(r) for r in recs)
          if t is not None and t["mesh"] == "single"]
    def frac(t):
        total = max(t["compute_s"], t["memory_s"], t["collective_s"])
        return t["compute_s"] / total if total else 1.0
    worst = min(ts, key=frac)
    coll = max(ts, key=lambda t: t["collective_s"]
               / max(t["compute_s"] + t["memory_s"], 1e-12))
    train = [t for t in ts if t["shape"] == "train_4k"]
    rep = max(train, key=lambda t: t["model_flops"]) if train else worst
    return {"worst_roofline": worst, "most_collective_bound": coll,
            "paper_representative": rep}


def main() -> None:
    recs = load_records()
    print(markdown_table(recs, "single"))
    print()
    targets = pick_hillclimb_targets(recs)
    for k, t in targets.items():
        print(f"{k}: {t['arch']} x {t['shape']} (dominant {t['dominant']})")


if __name__ == "__main__":
    main()
