"""Finding records, rendering, and the accepted-findings baseline.

A finding prints as ``file:line rule-id [severity] message``. The
baseline maps a finding's stable key — ``file::rule::snippet`` (the
stripped source line, so keys survive unrelated line-number drift) —
to the number of accepted occurrences. ``new_findings`` returns only
the occurrences beyond the accepted count, which is what CI fails on.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterable, List

SEVERITIES = ("error", "warn", "info")

# Committed at the repo root; python -m repro.analysis loads it
# automatically when present.
DEFAULT_BASELINE = ".repro-lint-baseline.json"

_BASELINE_VERSION = 1


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location.

    ``file`` is repo-relative (posix separators); ``snippet`` is the
    stripped source line the finding anchors to — it doubles as the
    stable component of the baseline key.
    """
    file: str
    line: int
    rule: str
    severity: str
    message: str
    snippet: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in "
                             f"{SEVERITIES}")

    @property
    def baseline_key(self) -> str:
        return f"{self.file}::{self.rule}::{self.snippet}"

    def render(self) -> str:
        return (f"{self.file}:{self.line} {self.rule} "
                f"[{self.severity}] {self.message}")


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    """Record every current finding as accepted (atomic rewrite)."""
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.baseline_key] = counts.get(f.baseline_key, 0) + 1
    payload = {"version": _BASELINE_VERSION,
               "findings": dict(sorted(counts.items()))}
    tmp = f"{path}.tmp"
    with open(tmp, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)


def load_baseline(path: str) -> Dict[str, int]:
    """Accepted-occurrence counts by baseline key ({} if no file)."""
    if not os.path.exists(path):
        return {}
    with open(path) as fh:
        payload = json.load(fh)
    if payload.get("version") != _BASELINE_VERSION:
        raise ValueError(
            f"baseline {path} has version {payload.get('version')!r}; "
            f"this checker reads version {_BASELINE_VERSION} — "
            "regenerate with --write-baseline")
    counts = payload.get("findings", {})
    if not all(isinstance(v, int) and v > 0 for v in counts.values()):
        raise ValueError(f"baseline {path} is malformed: occurrence "
                         "counts must be positive integers")
    return dict(counts)


def new_findings(findings: Iterable[Finding],
                 baseline: Dict[str, int]) -> List[Finding]:
    """The findings NOT covered by the baseline.

    Each baseline key absorbs up to its accepted count of occurrences
    (identical lines flagged by the same rule in the same file pool
    together); everything beyond that — or under a key the baseline
    has never seen — is new.
    """
    remaining = dict(baseline)
    out = []
    for f in sorted(findings):
        if remaining.get(f.baseline_key, 0) > 0:
            remaining[f.baseline_key] -= 1
        else:
            out.append(f)
    return out
