"""Analog over-the-air (A-OTA) gradient aggregation (paper Eq. 7).

    g_t = (1/N) * sum_n h_{n,t} * grad_n  +  xi_t

Three mathematically-identical realisations, picked by deployment mode:

1. ``ota_aggregate_stacked`` — *simulation*: per-client gradients stacked
   along a leading axis (produced by ``jax.vmap``/``lax.map`` over
   clients). Used by the CPU-sized paper-reproduction experiments.

2. ``ota_psum`` — *explicit collective*: used inside ``shard_map`` where
   each (pod, data) shard IS one client group. Each shard scales its local
   gradient by its own fading draw, a ``psum`` performs the superposition
   (the wireless MAC's "free sum" maps to one ICI all-reduce), and a
   shared-seed interference vector is added identically on every shard so
   replicas stay bit-identical.

3. ``faded_loss_weights`` + ``add_interference`` — *autodiff form* for the
   pjit/GSPMD path: since fading enters linearly,
   ``(1/N) sum_n h_n grad f_n = grad_w [(1/N) sum_n h_n f_n(w)]``,
   per-client fading is folded into per-example loss weights so a single
   global backward pass under pjit yields the faded aggregate; the
   interference is then added to the gradient pytree. This keeps XLA free
   to fuse/shard the backward pass (no custom collective needed) and is
   what the production ``train_step`` uses.

Realisation 1 has two backends (``OTAChannelConfig.backend``): ``"jnp"``
maps the faded sum and the interference over leaves, while ``"pallas"``
stacks the client gradients into one (N, d) slab (``repro.core.slab``)
and runs the fused ``ota_channel_slab`` kernel — fading reduction + CMS
interference synthesis in a single read of G. Both backends consume the
SAME per-leaf PRNG draws (``cms_inputs`` keyed exactly like
``add_interference``), so they agree to f32 rounding, not just in
distribution.

**The uplink pipeline** (``OTAChannelConfig.uplink``, PR 4). The slab
MAC is staged — transmit power control (folded into the effective
fading draw) -> quantize -> MAC superposition -> interference injection
-> receiver dequantize/scale. At ``uplink="f32"`` the quantize /
dequantize stages are identity and the round still executes the
original single fused ``ota_channel_slab`` launch, bit for bit. At
``uplink="int8"`` the transmitter quantizes its faded partial sum to an
int8 payload + per-128-block f32 scales in a fused quantize-on-write
epilogue (``ota_transmit_slab``) — stochastic rounding draws come from
the round key via ``channel.sr_inputs``, part of the shared PRNG
contract — and the receiver dequantizes and injects the interference
(``ota_receive_slab``). The jnp backend runs the op-mirrored ``ref``
implementations over the same slab layout and the same draws, so jnp
and pallas agree to within ONE quantization step per entry (f32
summation-order differences can flip individual stochastic-rounding
decisions; see ``kernels.ref.ota_transmit_ref``).
"""

from __future__ import annotations

import math
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.extend.random import threefry_2x32

from repro.core.channel import (CMS_E_FLOOR, CMS_U_BOUND, DL_FOLD,
                                FADING_FOLD, OTAChannelConfig, cms_inputs,
                                sample_fading, sample_interference, sr_inputs,
                                sr_kernel_seed)
from repro.core.slab import SlabSpec, make_slab_spec, slab_to_tree, stack_to_slab
from repro.core.tail_index import log_moment_stats
from repro.kernels.interpret import resolve_interpret
from repro.kernels.ota_channel import (INT8_MAX, LANE, ota_channel_slab,
                                       ota_receive_slab, ota_transmit_slab,
                                       pack_sign_slab)
from repro.kernels.ref import (ota_channel_ref, ota_receive_ref,
                               ota_transmit_ref)

PyTree = Any


def _leaf_keys(key: jax.Array, tree: PyTree) -> PyTree:
    """One deterministic PRNG key per leaf, stable under pytree structure."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, keys)


def add_interference(key: jax.Array, cfg: OTAChannelConfig, grads: PyTree) -> PyTree:
    """Add i.i.d. symmetric alpha-stable interference xi_t to every entry."""
    if not cfg.interference:
        return grads
    keys = _leaf_keys(key, grads)

    def noisy(g, k):
        xi = sample_interference(k, cfg, g.shape, dtype=jnp.float32)
        return (g.astype(jnp.float32) + xi).astype(g.dtype)

    return jax.tree.map(noisy, grads, keys)


# ---------------------------------------------------------------------------
# 1. Simulation path: stacked per-client gradients.
# ---------------------------------------------------------------------------

def _cms_slab_inputs(kx: jax.Array, spec: SlabSpec
                     ) -> Tuple[jax.Array, jax.Array]:
    """(u, e) CMS inputs over the whole slab, drawn per leaf with the SAME
    keys ``add_interference`` would use — the pallas backend consumes
    identical noise to the jnp backend. Padding gets (u=0, e=1), a fixed
    point of the CMS transform (xi == 0)."""
    us, es = [], []
    for i, shape in enumerate(spec.shapes):
        u, e = cms_inputs(jax.random.fold_in(kx, i), shape)
        us.append(u.reshape(-1))
        es.append(e.reshape(-1))
    pad = spec.padded - spec.total
    u = jnp.pad(jnp.concatenate(us), (0, pad))
    e = jnp.pad(jnp.concatenate(es), (0, pad), constant_values=1.0)
    return u, e


def _uniform_from_bits(bits: jax.Array, minval: float,
                       maxval: float) -> jax.Array:
    """``jax.random.uniform``'s f32 bit pipeline applied to raw threefry
    words: randomize the 23 mantissa bits at exponent 1, bitcast to
    [1, 2), shift-scale into [minval, maxval). Bitwise the values
    ``uniform`` produces at these counter positions (jax's threefry
    path with ``threefry_partitionable`` off — the repo-wide default;
    ``tests/test_overlap.py`` pins the equality, so a jax upgrade that
    reworks the pipeline fails loudly instead of silently skewing the
    interference draws)."""
    fb = (bits >> jnp.uint32(9)) | jnp.uint32(0x3F800000)
    f = jax.lax.bitcast_convert_type(fb, jnp.float32) - jnp.float32(1.0)
    lo = jnp.float32(minval)
    return jnp.maximum(lo, f * (jnp.float32(maxval) - lo) + lo)


def cms_slab_inputs_partial(kx: jax.Array, spec: SlabSpec, n_shards: int,
                            shard_index) -> Tuple[jax.Array, jax.Array]:
    """This shard's 1/P share of ``_cms_slab_inputs``, as full-width
    contribution rows whose element-wise sum over the mesh equals the
    full draw — the overlap engine's replacement for P devices each
    hashing the whole slab.

    The threefry draw behind ``jax.random.uniform`` is counter-based:
    for an l-length draw, output position j is lane 0 (j < h) or lane 1
    (j >= h) of counter pair (j, j + h), h = ceil(l / 2) (odd l pads
    counter value 0 and drops the final lane-1 word). Counters hash
    independently, so each shard evaluates only its contiguous share of
    the pairs — ``jax.extend.random.threefry_2x32`` on explicit counts
    — converts those words with ``_uniform_from_bits``, and scatters
    the values at their true positions into zero rows. The values ARE
    the full-draws-sliced contract's (same draw, same order) at 1/P the
    hashing work per device, and the combine rides the MAC
    reduce-scatter instead of a dedicated collective.

    The padding tail rides as u = 0, e = 0 (nobody's share writes it);
    ``_cms_slab_inputs`` pins e's tail to 1.0, so consumers of the
    combined rows re-pin it on their received slice (``pin_pad_tail``)
    before the CMS transform."""
    u_parts, e_parts = [], []
    for i, shape in enumerate(spec.shapes):
        kl = jax.random.fold_in(kx, i)
        ku, kw = jax.random.split(kl)
        l = math.prod(shape) if shape else 1
        h = (l + 1) // 2
        s = -(-h // n_shards)
        start = jnp.asarray(shard_index, jnp.uint32) * jnp.uint32(s)
        c0 = start + jnp.arange(s, dtype=jnp.uint32)
        if l % 2:
            # Odd draw: the last pair's lane-1 counter is the zero pad.
            c1 = jnp.where(c0 == jnp.uint32(h - 1), jnp.uint32(0),
                           c0 + jnp.uint32(h))
        else:
            c1 = c0 + jnp.uint32(h)
        counts = jnp.concatenate([c0, c1])

        def leaf_rows(key, convert):
            kd = jax.random.key_data(key)
            bits = threefry_2x32((kd[0], kd[1]), counts)
            vals = convert(bits)
            # Out-of-range pairs (the ragged last share) write into the
            # buffer's slack zone past h and are truncated away.
            z = jnp.zeros((n_shards * s,), jnp.float32)
            lane0 = jax.lax.dynamic_update_slice(z, vals[:s], (start,))
            lane1 = jax.lax.dynamic_update_slice(z, vals[s:], (start,))
            return jnp.concatenate([lane0[:h], lane1[:l - h]])

        u_parts.append(leaf_rows(
            ku, lambda b: _uniform_from_bits(b, -CMS_U_BOUND, CMS_U_BOUND)))
        e_parts.append(leaf_rows(
            kw, lambda b: jnp.maximum(
                -jnp.log(_uniform_from_bits(
                    b, float(jnp.finfo(jnp.float32).tiny), 1.0)),
                jnp.float32(CMS_E_FLOOR))))
    pad = spec.padded - spec.total
    u = jnp.pad(jnp.concatenate(u_parts), (0, pad))
    e = jnp.pad(jnp.concatenate(e_parts), (0, pad))
    return u, e


def pin_pad_tail(x, spec: SlabSpec, offset=None, width=None, value=1.0):
    """Pin a slab's (or shard slice's) padding tail to ``value`` —
    the post-combine fixup for ``cms_slab_inputs_partial``'s e rows
    (the CMS fixed point wants e = 1 on padding, but the partial rows
    sum the tail to 0)."""
    if width is None:
        width = spec.padded
    pos = jnp.arange(width)
    if offset is not None:
        pos = pos + offset
    return jnp.where(pos < spec.total, x, jnp.asarray(value, x.dtype))


def restore_zero_tail(x, spec: SlabSpec, offset=None, width=None):
    """Re-pin the slab's zero padding tail after a zero-folded wire.

    The 1-bit ``fold`` container cannot represent 0: padding coords in
    the slab's final PARTIAL 128-block (a block mixing real and padding
    coords has a nonzero scale) ride the wire as +1 and dequantize to
    +scale, which would let the resident engines accumulate updates in
    the tail that the pytree-materialising oracle discards. Padding is
    a layout artifact, not model state — a real deployment would never
    transmit those coordinates — so the slab layer re-masks the fold
    wire's outputs here, mirroring how ``_cms_slab_inputs`` pins the
    padding to the interference fixed point. Plain jnp, identical on
    every backend, applied ONLY on the fold wire (every other wire
    keeps the tail exact in-kernel, and their graphs must stay
    bitwise-untouched). Note the pilot-stats epilogue runs before this
    mask and sees the polluted tail — a per-slab-constant perturbation
    well inside the tail-index estimator's tolerance.

    ``offset``/``width`` select a shard's local slice of the mask (the
    sharded engine masks its own ``shard_len`` columns).
    """
    if x is None:
        return x
    if width is None:
        width = spec.padded
    pos = jnp.arange(width)
    if offset is not None:
        pos = pos + offset
    return jnp.where(pos < spec.total, x, jnp.zeros((), x.dtype))


def uplink_sr_slab_inputs(key: jax.Array, spec: SlabSpec,
                          shard_index=0) -> jax.Array:
    """Stochastic-rounding uniforms for one transmitter's payloads.

    Keyed from the ROUND key: the transmitter's linear shard index is
    folded in first (each device quantizes a different partial sum, so
    the draws are per-transmitter, like the fading; the single-device
    engines are transmitter 0, which makes the (1,)-mesh consume the
    exact same draws as the unsharded backends), then
    ``channel.sr_inputs``'s domain separator. Returns (2, spec.padded)
    f32 in [0, 1) — row 0 rounds the noisy faded payload, row 1 the
    clean diagnostic payload (only the sharded engine transmits the
    clean sum; single-device callers use row 0 and keep the shapes of
    the draw identical across engines)."""
    return sr_inputs(jax.random.fold_in(key, shard_index),
                     (2, spec.padded))


def downlink_sr_slab_inputs(key: jax.Array, d: int) -> jax.Array:
    """Stochastic-rounding uniforms for the int8 DOWNLINK broadcast
    quantizer, (d,) f32 in [0, 1).

    Keyed ``fold_in(round_key, DL_FOLD)`` — a domain separator disjoint
    from the fading/interference/uplink-SR sub-draws, so enabling the
    quantized downlink perturbs no uplink draw (the f32 downlink stays
    bitwise). One full-slab draw; the sharded engine slices it at the
    shard offset (full-draws-sliced, like every other per-entry draw)."""
    return jax.random.uniform(jax.random.fold_in(key, DL_FOLD), (d,))


def downlink_quantize_slab(w: jax.Array, r: jax.Array) -> jax.Array:
    """Simulated int8 model broadcast: quantize a (d,) f32 weight slab
    (or shard slice — d must be a multiple of 128, which every slab and
    shard slice is by the padding contract) to int8 with one f32 scale
    per 128-block (symmetric max|x|/127) and stochastic rounding ``r``
    (``downlink_sr_slab_inputs``), and return the dequantized (d,) f32
    the receivers reconstruct.

    Deliberately plain jnp, identical on every backend: the downlink
    wire carries d int8 + d/128 f32 (the byte model in
    benchmarks/train_loop_bench.py), but the reconstruction itself is
    elementwise and cheap, and a single spelling keeps jnp / pallas /
    pallas_sharded broadcasts bitwise-equal. Blocks are lane-aligned,
    so quantizing shard slices independently equals quantizing the full
    slab and slicing. All-zero blocks keep scale 1 -> payload 0 (the
    zero-tail contract). The server keeps the f32 master weights; only
    what CLIENTS see (their gradient point) is quantized.
    """
    d = w.shape[0]
    a = w.astype(jnp.float32).reshape(d // LANE, LANE)
    maxabs = jnp.max(jnp.abs(a), axis=1, keepdims=True)
    s = jnp.where(maxabs > 0.0, maxabs / INT8_MAX, 1.0)
    q = jnp.clip(jnp.floor(a / s + r.reshape(d // LANE, LANE)),
                 -INT8_MAX, INT8_MAX)
    return (q * s).reshape(-1)


def _interference_slab_inputs(kx: jax.Array, cfg: OTAChannelConfig,
                              spec: SlabSpec
                              ) -> Tuple[jax.Array, jax.Array, float]:
    """(u, e, scale) of the interference-injection stage; the disabled
    channel degenerates to the (0, 1, 0.0) fixed point (xi == 0)."""
    if cfg.interference:
        u, e = _cms_slab_inputs(kx, spec)
        return u, e, cfg.xi_scale
    return (jnp.zeros((spec.padded,), jnp.float32),
            jnp.ones((spec.padded,), jnp.float32), 0.0)


def ota_aggregate_slab(key: jax.Array, cfg: OTAChannelConfig,
                       client_grads: PyTree, spec: SlabSpec,
                       pilot_stats: bool = False, ef=None):
    """Slab-engine OTA MAC — the staged uplink pipeline, single device.

    ``spec`` is the slab layout of a SINGLE client's gradient (== the
    model parameters). Returns ``(g_slab, h, grads_slab, stats,
    ef_new)``: the noisy aggregate as a (spec.padded,) f32 slab (zero
    tail), the fading draw (N,), the stacked (N, spec.padded) f32
    gradient slab (returned so callers can derive clean-gradient
    statistics without re-stacking), — with ``pilot_stats=True`` — the
    (3,) residual log-moment statistics reduced by the receive/channel
    kernel's fused epilogue (``repro.core.tail_index`` turns them into
    the online alpha estimate; ``stats`` is None otherwise and the
    launches are the exact pre-stats ``pallas_call``s, the static-alpha
    path stays bitwise), and — when ``ef`` (this transmitter's carried
    (spec.padded,) error-feedback residual) is passed — the fresh
    residual to carry into the next round (None otherwise).

    ``uplink="f32"`` executes the original single fused
    ``ota_channel_slab`` launch (bitwise-identical to the pre-pipeline
    code). A quantized uplink (``"int8"`` / ``"sign"``) stages it:
    fused transmit with quantize-on-write (one transmitter — the whole
    MAC payload is quantized once; ``ef`` joins the faded partial
    before the quantizer and the residual is written in the same
    launch), then fused receive (dequantize + interference). The jnp
    backend runs the op-exact ``kernels.ref`` mirrors instead, over the
    same slab layout and the same draws.
    """
    n = jax.tree.leaves(client_grads)[0].shape[0]
    kh, kx = jax.random.split(key)
    h = sample_fading(kh, cfg, (n,))
    grads_slab = stack_to_slab(spec, client_grads)
    u, e, scale = _interference_slab_inputs(kx, cfg, spec)
    stats = None
    ef_new = None

    if cfg.uplink.quantized:
        qmode = cfg.uplink.mode
        zero_fold = cfg.uplink.zero_fold
        # The wire representation of the sign payload: when packed
        # ("fold"/"planes") the transmitted words go through
        # pack_sign_slab and the receiver's packed prologue — a bitwise
        # round trip, so taking the packed wire never perturbs values,
        # it just makes the trajectory ride the bits that actually move.
        packed = cfg.uplink.packed_sign
        # The sign quantizer is deterministic — it draws no SR uniforms
        # (fold_in is stateless, so skipping the draw perturbs nothing).
        stochastic = cfg.uplink.stochastic_rounding and qmode == "int8"
        # In-kernel SR (compiled pallas only): replace the host-drawn
        # uniforms with the kernel-seeded PRNG; interpret/jnp keep the
        # host path — it is the cross-backend parity oracle.
        inkernel = (stochastic and cfg.uplink.sr_inkernel
                    and cfg.backend != "jnp"
                    and not resolve_interpret(cfg.interpret))
        r = (uplink_sr_slab_inputs(key, spec)[0]
             if stochastic and not inkernel else None)
        want_ef = ef is not None
        if cfg.backend == "jnp":
            tx = ota_transmit_ref(grads_slab, h, quantize=True, r=r,
                                  stochastic=stochastic, qmode=qmode,
                                  zero_fold=zero_fold,
                                  ef=ef, return_residual=want_ef)
            payload = (pack_sign_slab(tx[0][None],
                                      planes=(packed == "planes"))
                       if packed else tx[0][None])
            g_slab = ota_receive_ref(payload, tx[1][None], u, e,
                                     alpha=cfg.alpha, scale=scale,
                                     packed=packed,
                                     pilot_stats=pilot_stats)
        else:
            sr_seed = sr_kernel_seed(key)[0] if inkernel else None
            tx = ota_transmit_slab(grads_slab, h, quantize=True, r=r,
                                   stochastic=stochastic, qmode=qmode,
                                   zero_fold=zero_fold, sr_seed=sr_seed,
                                   ef=ef, return_residual=want_ef,
                                   interpret=cfg.interpret)
            payload = (pack_sign_slab(tx[0][None],
                                      planes=(packed == "planes"))
                       if packed else tx[0][None])
            g_slab = ota_receive_slab(payload, tx[1][None], u, e,
                                      alpha=cfg.alpha, scale=scale,
                                      packed=packed,
                                      pilot_stats=pilot_stats,
                                      interpret=cfg.interpret)
        if want_ef:
            ef_new = tx[2]
        if pilot_stats:
            g_slab, stats = g_slab
        if cfg.uplink.zero_fold:
            g_slab = restore_zero_tail(g_slab, spec)
            ef_new = restore_zero_tail(ef_new, spec)
        return g_slab, h, grads_slab, stats, ef_new

    if cfg.backend == "jnp":
        g_slab = ota_channel_ref(grads_slab, h, u, e, alpha=cfg.alpha,
                                 scale=scale, pilot_stats=pilot_stats)
    else:
        g_slab = ota_channel_slab(grads_slab, h, u, e, alpha=cfg.alpha,
                                  scale=scale, pilot_stats=pilot_stats,
                                  interpret=cfg.interpret)
    if pilot_stats:
        g_slab, stats = g_slab
    return g_slab, h, grads_slab, stats, ef_new


def interference_log_moment_stats(kx: jax.Array, cfg: OTAChannelConfig,
                                  tree: PyTree) -> jax.Array:
    """The per-leaf jnp mirror of the kernels' pilot-stats epilogue.

    Re-draws the interference of this round from the SAME per-leaf keys
    ``add_interference`` consumed (``fold_in(kx, leaf_index)`` — the
    shared PRNG contract, so the values are literally the ones already
    injected) and reduces them to the ``[count, sum log|r|,
    sum log^2|r|]`` statistics; per-leaf 3-vectors add, exactly like the
    sharded engine's per-slice psum. Returns zeros when the channel
    injects no interference. Standalone form; the round hot path uses
    ``_add_interference_with_stats`` to sample each leaf only once.
    """
    if not cfg.interference:
        return jnp.zeros((3,), jnp.float32)
    keys = _leaf_keys(kx, tree)
    stats = jnp.zeros((3,), jnp.float32)
    for g, k in zip(jax.tree.leaves(tree), jax.tree.leaves(keys)):
        xi = sample_interference(k, cfg, g.shape, dtype=jnp.float32)
        stats = stats + log_moment_stats(xi)
    return stats


def _add_interference_with_stats(kx: jax.Array, cfg: OTAChannelConfig,
                                 grads: PyTree) -> Tuple[PyTree, jax.Array]:
    """``add_interference`` + the pilot-stats reduction in ONE pass over
    the per-leaf draws (the tracked jnp round would otherwise synthesize
    the full interference vector twice)."""
    if not cfg.interference:
        return grads, jnp.zeros((3,), jnp.float32)
    leaves, treedef = jax.tree.flatten(grads)
    stats = jnp.zeros((3,), jnp.float32)
    noisy = []
    for i, g in enumerate(leaves):
        xi = sample_interference(jax.random.fold_in(kx, i), cfg, g.shape,
                                 dtype=jnp.float32)
        noisy.append((g.astype(jnp.float32) + xi).astype(g.dtype))
        stats = stats + log_moment_stats(xi)
    return jax.tree.unflatten(treedef, noisy), stats


def ota_aggregate_stacked(key: jax.Array, cfg: OTAChannelConfig,
                          client_grads: PyTree, pilot_stats: bool = False):
    """OTA-aggregate gradients stacked on a leading client axis.

    Dispatches on ``cfg.backend``: the jnp path maps the faded sum over
    leaves and adds per-leaf interference; the pallas path routes through
    ``ota_aggregate_slab`` (one fused kernel) and restores the pytree.
    A quantized uplink routes through the slab pipeline on EVERY backend
    (the payload/scale layout is a slab concept; the jnp backend uses
    the op-exact ``kernels.ref`` mirrors inside ``ota_aggregate_slab``).

    Args:
      key: PRNG key for this communication round.
      cfg: channel configuration.
      client_grads: pytree whose leaves have shape (N, ...) — gradient of
        client n at leaf[..., n, ...].
      pilot_stats: also return the (3,) residual log-moment statistics
        of this round's interference (fused kernel epilogues on the
        pallas backends, the per-leaf mirror on jnp) for the online
        tail-index tracker.

    Returns:
      (g_t, h): the noisy aggregated gradient pytree (leaf shape (...)) and
      the fading draw h of shape (N,) (returned for logging/analysis);
      ``(g_t, h, stats)`` when ``pilot_stats=True``.
    """
    if cfg.backend in ("pallas", "pallas_sharded") or cfg.uplink.quantized:
        spec = make_slab_spec(jax.tree.map(
            lambda g: jax.ShapeDtypeStruct(g.shape[1:], g.dtype),
            client_grads))
        g_slab, h, _, stats, _ = ota_aggregate_slab(key, cfg, client_grads,
                                                    spec,
                                                    pilot_stats=pilot_stats)
        g_t = slab_to_tree(spec, g_slab)
        return (g_t, h, stats) if pilot_stats else (g_t, h)

    n = jax.tree.leaves(client_grads)[0].shape[0]
    kh, kx = jax.random.split(key)
    h = sample_fading(kh, cfg, (n,))

    def agg(g):
        hb = h.reshape((n,) + (1,) * (g.ndim - 1)).astype(g.dtype)
        return jnp.sum(hb * g, axis=0) / n

    g_t = jax.tree.map(agg, client_grads)
    if pilot_stats:
        noisy, stats = _add_interference_with_stats(kx, cfg, g_t)
        return noisy, h, stats
    return add_interference(kx, cfg, g_t), h


# ---------------------------------------------------------------------------
# 2. Explicit-collective path for shard_map (client == mesh shard group).
# ---------------------------------------------------------------------------

def linear_shard_index(axis_names: Sequence[str]) -> jax.Array:
    """Row-major linear index of this shard over ``axis_names`` — the
    same order PartitionSpec uses to lay blocks of a sharded array out,
    so slicing a replicated array at ``idx * block`` matches what an
    in_spec ``P(axis_names)`` would have delivered. Call inside
    ``shard_map``.
    """
    # psum of a literal 1 constant-folds to the static axis size on every
    # jax version; jax.lax.axis_size only exists on newer releases.
    sizes = [jax.lax.psum(1, a) for a in axis_names]
    idx = jnp.zeros((), jnp.int32)
    for a, s in zip(axis_names, sizes):
        idx = idx * s + jax.lax.axis_index(a)
    return idx


def ota_psum(local_grad: PyTree, key: jax.Array, cfg: OTAChannelConfig,
             axis_names: Sequence[str]) -> PyTree:
    """OTA aggregation as a collective; call inside ``shard_map``.

    Each shard holds the gradient of its own client (group). The fading
    coefficient of shard n is drawn by folding the shard's linear index
    over ``axis_names`` into the round key, so every shard can compute all
    coefficients without communication. The psum over ``axis_names``
    realises the superposition; the interference is sampled from the
    *round* key (not the shard key) and hence is identical on all shards,
    exactly like the single RF front end of the server.

    This legacy per-leaf collective predates the staged uplink pipeline
    and only speaks the analog f32 wire; the quantized uplink is a slab
    concept (per-128-block payload/scale layout) and lives in
    ``repro.core.shard``. Refuse rather than silently run f32.
    """
    if cfg.uplink.quantized:
        raise NotImplementedError(
            "ota_psum / make_sharded_round_step do not implement the "
            f"quantized uplink (uplink={cfg.uplink.mode!r}); use the "
            "slab engine (backend='pallas_sharded', repro.core.shard) "
            "for the int8 MAC")
    axis_names = tuple(axis_names)
    n = math.prod(jax.lax.psum(1, a) for a in axis_names)
    idx = linear_shard_index(axis_names)
    kh, kx = jax.random.split(key)
    h_all = sample_fading(kh, cfg, (n,))
    h_n = jax.lax.dynamic_index_in_dim(h_all, idx, keepdims=False)

    scaled = jax.tree.map(lambda g: (h_n.astype(g.dtype) * g), local_grad)
    summed = jax.lax.psum(scaled, axis_names)
    g_t = jax.tree.map(lambda g: g / n, summed)
    return add_interference(kx, cfg, g_t)


# ---------------------------------------------------------------------------
# 3. Autodiff path for pjit: fading as per-example loss weights.
# ---------------------------------------------------------------------------

def faded_loss_weights(key: jax.Array, cfg: OTAChannelConfig,
                       client_ids: jax.Array, n_clients: int) -> Tuple[jax.Array, jax.Array]:
    """Per-example weights realising the faded average inside one backward.

    With per-client batch size b and global batch B = N*b,
    ``(1/N) sum_n h_n * mean_{i in B_n} l_i  =  mean_i  h_{c(i)} * l_i``.
    So the weighted *mean* loss over the global batch with weights
    ``h[client_ids]`` has gradient exactly equal to the faded OTA average
    (before interference).

    Args:
      key: round key (the fading sub-draw is derived from it).
      cfg: channel config.
      client_ids: int32 (batch,) mapping each example row to its client.
      n_clients: N.

    Returns:
      (weights, h): weights of shape (batch,) and the h draw (N,).
    """
    h = sample_fading(jax.random.fold_in(key, FADING_FOLD), cfg, (n_clients,))
    return h[client_ids], h
