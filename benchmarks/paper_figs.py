"""Paper-figure reproductions (CPU-sized, synthetic data stand-ins).

One function per figure of the paper; all run the REAL system end to end
(clients -> OTA MAC -> adaptive server). Returns records used by
benchmarks/run.py and EXPERIMENTS.md §Paper.

  fig2  — Adam-OTA vs AdaGrad-OTA vs FedAvgM-OTA, non-iid Dir=0.1, a=1.5
  fig3  — same at a=1.8, scale=0.01 (milder channel)
  fig4  — beta2 sweep for Adam-OTA
  fig5  — tail-index (alpha) sweep for AdaGrad-OTA
  fig6  — client-count (N) sweep for AdaGrad-OTA
  fig7  — Dirichlet heterogeneity sweep for AdaGrad-OTA
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step, run_rounds)
from repro.data import FederatedBatcher, gaussian_mixture, synthetic_images
from repro.models.vision import accuracy, logistic_regression, mlp, resnet_tiny

ROUNDS = 80


def _run(optimizer: str, *, task: str = "logreg", alpha=1.5, scale=0.1,
         n_clients=50, dir_alpha=0.1, lr=0.05, beta2=0.3, rounds=ROUNDS,
         seed=0) -> Dict:
    if task == "logreg":
        data = gaussian_mixture(6000, 32, 10, seed=seed)
        model = logistic_regression(32, 10)
        batch_size = 16
    elif task == "mlp":
        data = gaussian_mixture(6000, 32, 10, seed=seed)
        model = mlp(32, 10, hidden=64)
        batch_size = 16
    else:  # "cnn" — the ResNet-tiny / CIFAR-like task
        data = synthetic_images(3000, 16, 3, 10, seed=seed)
        model = resnet_tiny(10, channels=(8, 16), blocks_per_stage=1)
        batch_size = 8

    fb = FederatedBatcher(data, n_clients, batch_size, dir_alpha=dir_alpha,
                          seed=seed)
    ch = OTAChannelConfig(alpha=alpha, xi_scale=scale)
    ad = AdaptiveConfig(optimizer=optimizer, lr=lr, alpha=alpha, beta2=beta2)
    rs = make_round_step(model.loss_fn, ch, ad, FLConfig(n_clients=n_clients))
    params = model.init(jax.random.key(seed))
    state = init_server(params, ad)

    def batch_fn(t, key):
        b = fb(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    t0 = time.time()
    params, state, hist = run_rounds(rs, params, state,
                                     jax.random.key(seed + 1), batch_fn,
                                     rounds)
    dt = time.time() - t0
    losses = [h["loss"] for h in hist]
    acc = accuracy(model, params, jnp.asarray(data.x), data.y)
    return dict(optimizer=optimizer, task=task, alpha=alpha, scale=scale,
                n_clients=n_clients, dir_alpha=dir_alpha, beta2=beta2,
                final_loss=float(np.mean(losses[-10:])),
                # convergence-speed proxy: mean loss over the first half of
                # training (the paper's figs compare convergence CURVES)
                speed_loss=float(np.mean(losses[:max(rounds // 2, 1)])),
                first_loss=losses[0], accuracy=acc,
                seconds=round(dt, 1), us_per_round=dt / rounds * 1e6,
                loss_curve=[round(l, 4) for l in losses])


def fig2(task: str = "logreg") -> List[Dict]:
    """ADOTA vs FedAvgM under heavy-tailed channel (a=1.5, Dir=0.1).

    Channel scale calibrated to 0.3 for the synthetic stand-in: the
    paper's 0.1 is relative to ResNet-on-CIFAR gradient magnitudes; on
    the (easier) gaussian-mixture logreg task 0.1 barely perturbs
    training and ALL methods converge — 0.3 restores the signal-to-
    interference regime the paper operates in (documented substitution).
    """
    out = []
    for opt, lr in [("adam_ota", 0.05), ("adagrad_ota", 0.05),
                    ("fedavgm", 0.01)]:
        out.append(_run(opt, task=task, lr=lr, scale=0.3))
    return out


def fig3() -> List[Dict]:
    """Milder channel: a=1.8, scale=0.01 (paper Fig. 3 setup)."""
    out = []
    for opt, lr in [("adam_ota", 0.05), ("adagrad_ota", 0.05),
                    ("fedavgm", 0.01)]:
        out.append(_run(opt, alpha=1.8, scale=0.01, lr=lr))
    return out


def fig4() -> List[Dict]:
    """beta2 sweep (paper found 0.3 best, extremes worse)."""
    return [_run("adam_ota", beta2=b2) for b2 in (0.1, 0.3, 0.6, 0.9)]


def fig5() -> List[Dict]:
    """alpha sweep for AdaGrad-OTA (heavier tail -> slower)."""
    return [_run("adagrad_ota", alpha=a, scale=0.3) for a in
            (1.2, 1.5, 1.8, 2.0)]


def fig6() -> List[Dict]:
    """client count sweep (more clients -> better, Remark 12).

    Strong-interference regime (scale 0.5): Upsilon's 1/N^{a/2} term
    damps the FADING noise, so the effect is visible when the channel
    actually stresses training (calibrated like fig2)."""
    return [_run("adagrad_ota", n_clients=n, dir_alpha=0.2, scale=0.5)
            for n in (2, 10, 50, 100)]


def fig7() -> List[Dict]:
    """heterogeneity sweep (smaller Dir -> slower convergence). Compared
    on the convergence-speed proxy (mean first-half loss), the quantity
    the paper's Fig. 7 curves actually show; run on the non-convex MLP
    where client drift matters."""
    return [_run("adagrad_ota", task="mlp", dir_alpha=d, scale=0.3)
            for d in (0.05, 0.1, 0.5, 10.0)]


def beyond_yogi() -> List[Dict]:
    """Beyond-paper: FedYogi-style alpha-power variant vs Adam-OTA."""
    return [_run("yogi_ota"), _run("adam_ota")]
