"""Sharding rules: every full-size config gets valid PartitionSpecs
(divisibility respected) — eval_shape only, no allocation."""

import math

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models.model import build_model, partition_spec

MSIZE = 16
DSIZE = 16


def _check_divisible(shapes, specs, axis_sizes):
    bad = []

    def chk(path, leaf, spec):
        for dim, part in zip(leaf.shape, spec):
            if part is None:
                continue
            parts = (part,) if isinstance(part, str) else part
            size = math.prod(axis_sizes[p] for p in parts)
            if dim % size != 0:
                bad.append((jax.tree_util.keystr(path), leaf.shape, spec))

    jax.tree_util.tree_map_with_path(chk, shapes, specs)
    return bad


@pytest.mark.parametrize("arch", ARCHS)
def test_partition_specs_valid(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = partition_spec(cfg, shapes, "model", MSIZE)
    # same structure
    assert jax.tree.structure(shapes) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))
    bad = _check_divisible(shapes, specs,
                           {"model": MSIZE, "data": DSIZE, "pod": 2})
    assert not bad, bad[:5]
    # spec rank must equal leaf rank
    def rank_ok(l, s):
        assert len(s) == len(l.shape)
    jax.tree.map(rank_ok, shapes, specs,
                 is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", ["qwen3-14b", "kimi-k2-1t-a32b", "rwkv6-7b"])
def test_model_axis_actually_used(arch):
    """Tensor parallelism must actually shard the big tensors."""
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    specs = partition_spec(cfg, shapes, "model", MSIZE)
    total, sharded = 0, 0

    def acc(l, s):
        nonlocal total, sharded
        n = math.prod(l.shape)
        total += n
        if any(p is not None for p in s):
            sharded += n

    jax.tree.map(acc, shapes, specs, is_leaf=lambda x: isinstance(x, P))
    assert sharded / total > 0.9   # >90% of params are model-sharded


def test_fsdp_shards_more():
    cfg = get_config("qwen3-14b")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.key(0))
    base = partition_spec(cfg, shapes, "model", MSIZE)
    fsdp = partition_spec(cfg, shapes, "model", MSIZE,
                          fsdp_axis="data", fsdp_size=DSIZE)

    def count_axes(specs):
        n = 0
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            n += sum(p is not None for p in s)
        return n

    assert count_axes(fsdp) > count_axes(base)


def test_cache_partition_specs():
    from repro.compat import make_auto_mesh
    from repro.launch.specs import cache_partition_spec
    cfg = get_config("qwen3-14b")
    model = build_model(cfg)
    import functools
    cache_shapes = jax.eval_shape(functools.partial(model.init_cache, 128,
                                                    1024))
    mesh = make_auto_mesh((1,), ("data",))
    specs = cache_partition_spec(cache_shapes, mesh, 128, lambda n: False)
    # k/v cache batch dim sharded over data
    kspec = specs["layers"]["kv"]["k"]
    assert kspec[1] in ("data", ("data",))   # P normalises 1-tuples
    # pos replicated
    assert all(p is None for p in specs["layers"]["kv"]["pos"])
