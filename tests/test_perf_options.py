"""The §Perf optimization paths must be numerically equivalent to the
baselines they replace (same math, different blocking/sharding)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.model import build_model


def _logits(cfg, toks):
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    out, _ = model.forward(params, {"tokens": toks})
    return out


def test_window_block_model_equivalence():
    """Block-local window attention == full masked window attention,
    end to end through a windowed arch."""
    base = dataclasses.replace(smoke_config("starcoder2-15b"),
                               param_dtype="float32", window=8)
    toks = jax.random.randint(jax.random.key(2), (2, 40), 0, base.vocab)
    a = _logits(base, toks)
    b = _logits(dataclasses.replace(base, window_block=True), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_kv_chunk_model_equivalence():
    base = dataclasses.replace(smoke_config("qwen3-14b"),
                               param_dtype="float32")
    toks = jax.random.randint(jax.random.key(2), (2, 33), 0, base.vocab)
    a = _logits(base, toks)
    b = _logits(dataclasses.replace(base, kv_chunk=8), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_ssm_chunk_model_equivalence():
    base = dataclasses.replace(smoke_config("hymba-1.5b"),
                               param_dtype="float32")
    toks = jax.random.randint(jax.random.key(2), (2, 40), 0, base.vocab)
    a = _logits(base, toks)
    b = _logits(dataclasses.replace(base, ssm_chunk=8), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_moe_sharded_model_equivalence():
    """shard_map expert parallelism == plain dispatch on a 1x1 mesh
    (exactness requires no capacity drops -> generous factor)."""
    from repro.compat import make_auto_mesh
    from repro.models.moe import clear_moe_sharding, set_moe_sharding

    base = dataclasses.replace(smoke_config("qwen3-moe-235b-a22b"),
                               param_dtype="float32", capacity_factor=8.0)
    toks = jax.random.randint(jax.random.key(2), (2, 16), 0, base.vocab)
    a = _logits(base, toks)
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    set_moe_sharding(mesh, ("data",), "model")
    try:
        b = _logits(dataclasses.replace(base, moe_sharded=True), toks)
    finally:
        clear_moe_sharding()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_moe_sharded_capacity_is_per_shard():
    """The sharded path's capacity is computed from local tokens (the
    per-shard load), and dropped slots still yield finite outputs."""
    from repro.compat import make_auto_mesh
    from repro.models.moe import (MoEConfig, clear_moe_sharding, moe_apply,
                                  moe_init, set_moe_sharding)
    cfg = MoEConfig(d_model=16, n_experts=4, top_k=2, d_ff=32,
                    capacity_factor=0.1, sharded=True)
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    mesh = make_auto_mesh((1, 1), ("data", "model"))
    set_moe_sharding(mesh, ("data",), "model")
    try:
        y, aux = moe_apply(p, cfg, jax.random.normal(jax.random.key(1),
                                                     (1, 32, 16)))
    finally:
        clear_moe_sharding()
    assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.isfinite(aux))


def test_decode_consistency_with_perf_options():
    """prefill+decode == forward with window_block + ssm_chunk enabled."""
    cfg = dataclasses.replace(smoke_config("hymba-1.5b"),
                              param_dtype="float32", ssm_chunk=8,
                              window_block=True, window=8)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(2), (B, S + 1), 0, cfg.vocab)
    lf, _ = model.forward(params, {"tokens": toks})
    pl, cache = model.prefill(params, {"tokens": toks[:, :S]},
                              length=S + cfg.n_meta_tokens + 8)
    dl, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                              jnp.asarray(S))
    rel = lambda a, b: float(jnp.max(jnp.abs(a - b))
                             / (jnp.max(jnp.abs(b)) + 1e-9))
    assert rel(pl[:, 0], lf[:, S - 1]) < 2e-4
    assert rel(dl[:, 0], lf[:, S]) < 2e-4
