"""OTA aggregation: unbiasedness, equivalence of the three realisations."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.channel import OTAChannelConfig
from repro.core.ota import (add_interference, faded_loss_weights,
                            ota_aggregate_stacked, ota_psum)


def test_aggregate_noiseless_is_mean():
    cfg = OTAChannelConfig(fading="none", interference=False)
    grads = {"w": jnp.arange(12.0).reshape(4, 3)}   # 4 clients
    g, h = ota_aggregate_stacked(jax.random.key(0), cfg, grads)
    np.testing.assert_allclose(np.asarray(g["w"]),
                               np.arange(12).reshape(4, 3).mean(0), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(h), 1.0)


def test_aggregate_unbiased_under_fading():
    """Remark 1: E[g_t] = mu_c * grad mean."""
    cfg = OTAChannelConfig(fading="rayleigh", mu_c=1.0, interference=False)
    grads = {"w": jnp.ones((8, 16))}
    acc = jnp.zeros(16)
    trials = 3000
    for i in range(trials):
        g, _ = ota_aggregate_stacked(jax.random.key(i), cfg, grads)
        acc = acc + g["w"]
    assert abs(float(acc.mean()) / trials - 1.0) < 0.02


def test_interference_matches_channel_stats():
    cfg = OTAChannelConfig(alpha=1.6, xi_scale=0.2, fading="none")
    zero = {"w": jnp.zeros(200_000)}
    g = add_interference(jax.random.key(3), cfg, zero)
    from repro.core.tail_index import log_moment_estimate
    a, c = log_moment_estimate(g["w"])
    assert abs(float(a) - 1.6) < 0.05
    assert abs(float(c) - 0.2) < 0.03


@settings(max_examples=20, deadline=None)
@given(perm_seed=st.integers(0, 2**31 - 1))
def test_noiseless_aggregate_permutation_invariant(perm_seed):
    """Clients are exchangeable through the MAC when fading is off."""
    cfg = OTAChannelConfig(fading="none", interference=False)
    g0 = jax.random.normal(jax.random.key(1), (6, 5))
    perm = jax.random.permutation(jax.random.key(perm_seed), 6)
    a, _ = ota_aggregate_stacked(jax.random.key(2), cfg, {"w": g0})
    b, _ = ota_aggregate_stacked(jax.random.key(2), cfg, {"w": g0[perm]})
    np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                               rtol=1e-5)


def test_faded_loss_weights_equal_faded_gradient():
    """The pjit path (fading as per-example loss weights) must produce
    exactly (1/N) sum_n h_n grad_n — the core identity of the production
    train_step."""
    cfg = OTAChannelConfig(fading="rayleigh", interference=False)
    n_clients, per_client, d = 4, 3, 5
    b = n_clients * per_client
    key = jax.random.key(7)
    x = jax.random.normal(jax.random.key(1), (b, d))
    y = jax.random.normal(jax.random.key(2), (b,))
    w0 = jnp.zeros(d)
    client_ids = jnp.arange(b) * n_clients // b

    weights, h = faded_loss_weights(key, cfg, client_ids, n_clients)

    # Path A: weighted-mean loss, one backward.
    def weighted_loss(w):
        per = (x @ w - y) ** 2
        return jnp.mean(per * weights)

    gA = jax.grad(weighted_loss)(w0)

    # Path B: per-client grads, explicit faded average.
    def client_loss(w, c):
        sl = slice(c * per_client, (c + 1) * per_client)
        return jnp.mean((x[sl] @ w - y[sl]) ** 2)

    gB = sum(h[c] * jax.grad(client_loss)(w0, c)
             for c in range(n_clients)) / n_clients
    np.testing.assert_allclose(np.asarray(gA), np.asarray(gB), rtol=1e-5)


def test_ota_psum_single_shard_matches_stacked():
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_auto_mesh, shard_map
    mesh = make_auto_mesh((1,), ("data",))
    cfg = OTAChannelConfig(alpha=1.5, xi_scale=0.1, fading="rayleigh")
    local = {"w": jnp.arange(6.0)}
    key = jax.random.key(11)

    out = shard_map(
        lambda g: ota_psum(g, key, cfg, ("data",)),
        mesh, ({"w": P()},), {"w": P()})(local)
    ref, _ = ota_aggregate_stacked(key, cfg, {"w": local["w"][None]})
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               rtol=1e-5)


def test_interference_deterministic_in_key():
    cfg = OTAChannelConfig()
    z = {"a": jnp.zeros(64), "b": jnp.zeros((4, 4))}
    g1 = add_interference(jax.random.key(5), cfg, z)
    g2 = add_interference(jax.random.key(5), cfg, z)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # different leaves get different noise
    assert not np.allclose(np.asarray(g1["a"][:16]),
                           np.asarray(g1["b"]).reshape(-1))
