"""The paper's own experiment models, CPU-sized.

The paper trains ResNet-18/34 on CIFAR-10/100 and logistic regression on
EMNIST. Offline + CPU-only, we use: logistic regression (exactly the
paper's convex task), an MLP, and "ResNet-tiny" — a small residual
conv net with the same structural ingredients as ResNet-18 (conv stem,
2-conv residual blocks with projection shortcuts, global average pool).
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class TaskModel(NamedTuple):
    init: Callable
    loss_fn: Callable         # (params, batch{x,y}) -> scalar
    predict: Callable         # (params, x) -> logits
    name: str


def _xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def accuracy(model: TaskModel, params, x, y, batch: int = 4096) -> float:
    correct = 0
    for i in range(0, len(y), batch):
        logits = model.predict(params, x[i:i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y[i:i + batch]))
    return correct / len(y)


def logistic_regression(d: int, n_classes: int) -> TaskModel:
    def init(key):
        return {"w": jnp.zeros((d, n_classes), jnp.float32),
                "b": jnp.zeros((n_classes,), jnp.float32)}

    def predict(p, x):
        return x @ p["w"] + p["b"]

    def loss_fn(p, batch):
        return _xent(predict(p, batch["x"]), batch["y"])

    return TaskModel(init, loss_fn, predict, "logreg")


def mlp(d: int, n_classes: int, hidden: int = 128) -> TaskModel:
    def init(key):
        k1, k2 = jax.random.split(key)
        s1, s2 = 1 / math.sqrt(d), 1 / math.sqrt(hidden)
        return {"w1": jax.random.normal(k1, (d, hidden)) * s1,
                "b1": jnp.zeros((hidden,)),
                "w2": jax.random.normal(k2, (hidden, n_classes)) * s2,
                "b2": jnp.zeros((n_classes,))}

    def predict(p, x):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        return h @ p["w2"] + p["b2"]

    def loss_fn(p, batch):
        return _xent(predict(p, batch["x"]), batch["y"])

    return TaskModel(init, loss_fn, predict, "mlp")


# ---------------------------------------------------------------------------
# ResNet-tiny.
# ---------------------------------------------------------------------------

def _conv(p, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, p, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_init(key, k, cin, cout):
    return jax.random.normal(key, (k, k, cin, cout)) * math.sqrt(2.0 / (k * k * cin))


def _groupnorm(scale, bias, x, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * scale + bias


def resnet_tiny(n_classes: int, channels=(16, 32, 64), blocks_per_stage=2,
                in_channels: int = 3) -> TaskModel:
    """Residual conv net (GroupNorm instead of BatchNorm — no running
    stats to aggregate across FL clients, a standard FL substitution)."""

    def init(key):
        keys = iter(jax.random.split(key, 64))
        p = {"stem": _conv_init(next(keys), 3, in_channels, channels[0]),
             "gn0_s": jnp.ones((channels[0],)), "gn0_b": jnp.zeros((channels[0],))}
        cin = channels[0]
        for si, c in enumerate(channels):
            for bi in range(blocks_per_stage):
                pre = f"s{si}b{bi}"
                stride = 2 if (bi == 0 and si > 0) else 1
                p[pre + "_c1"] = _conv_init(next(keys), 3, cin, c)
                p[pre + "_g1s"], p[pre + "_g1b"] = jnp.ones((c,)), jnp.zeros((c,))
                p[pre + "_c2"] = _conv_init(next(keys), 3, c, c)
                p[pre + "_g2s"], p[pre + "_g2b"] = jnp.ones((c,)), jnp.zeros((c,))
                if stride != 1 or cin != c:
                    p[pre + "_proj"] = _conv_init(next(keys), 1, cin, c)
                cin = c
        p["head_w"] = jnp.zeros((cin, n_classes))
        p["head_b"] = jnp.zeros((n_classes,))
        return p

    def predict(p, x):
        h = _groupnorm(p["gn0_s"], p["gn0_b"], _conv(p["stem"], x))
        h = jax.nn.relu(h)
        for si, c in enumerate(channels):
            for bi in range(blocks_per_stage):
                pre = f"s{si}b{bi}"
                stride = 2 if (bi == 0 and si > 0) else 1
                y = _conv(p[pre + "_c1"], h, stride)
                y = jax.nn.relu(_groupnorm(p[pre + "_g1s"], p[pre + "_g1b"], y))
                y = _conv(p[pre + "_c2"], y)
                y = _groupnorm(p[pre + "_g2s"], p[pre + "_g2b"], y)
                sc = h if (pre + "_proj") not in p else _conv(p[pre + "_proj"],
                                                              h, stride)
                h = jax.nn.relu(y + sc)
        pooled = h.mean(axis=(1, 2))
        return pooled @ p["head_w"] + p["head_b"]

    def loss_fn(p, batch):
        return _xent(predict(p, batch["x"]), batch["y"])

    return TaskModel(init, loss_fn, predict, "resnet_tiny")
