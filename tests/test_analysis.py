"""repro-lint (PR 10): every rule fires on its should-flag fixture,
stays silent on the should-pass twin, and the live repo is clean
against the committed baseline.

The AST-tier tests feed in-memory sources through
``analyze_sources({relpath: source})`` with fabricated repo-relative
paths, so each rule's scoping (round bodies, zero-tail modules, the
kernels package) is exercised exactly as on the real tree. The jaxpr
tier is tested twice: the detection mechanics on hand-built traced
functions (a forked-draw pair, an int8 downcast), and the real engine
(all three backends' ledgers identical, no downcast, donation fully
aliased).
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (Finding, load_baseline, new_findings,
                            write_baseline)
from repro.analysis.ast_rules import analyze_repo, analyze_sources

ROOT = Path(__file__).resolve().parents[1]

# Fixture paths chosen to land in each rule's scope.
CORE = "src/repro/core/ota.py"
KERNEL = "src/repro/kernels/ota_channel.py"
REF = "src/repro/kernels/ref.py"
OTHER = "src/repro/launch/train.py"

# A registry for fixtures (isolated from the live one).
REG = {"SR_FOLD": 0x5A8, "DL_FOLD": 0xD01}


def rules_of(findings, rule):
    return [f for f in findings if f.rule == rule]


def analyze_one(path, source, **kw):
    kw.setdefault("registry", REG)
    return analyze_sources({path: source}, **kw)


# ---------------------------------------------------------------------------
# fold rules


def test_fold_collision_fires_on_duplicate_value():
    src = "A_FOLD = 0x5A8\nB_FOLD = 0x5A8\n"
    hits = rules_of(analyze_one(CORE, src,
                                registry={"A_FOLD": 0x5A8,
                                          "B_FOLD": 0x5A8}),
                    "fold-collision")
    # once for the registry sharing a value, once for the second def
    assert len(hits) == 2
    assert any(f.line == 2 and "B_FOLD" in f.message for f in hits)
    assert all(f.severity == "error" for f in hits)


def test_fold_drift_fires_on_value_disagreement():
    hits = rules_of(analyze_one(CORE, "SR_FOLD = 0x999\n"), "fold-drift")
    assert len(hits) == 1
    assert "0x999" in hits[0].message and "0x5a8" in hits[0].message


def test_fold_drift_fires_on_unledgered_constant():
    hits = rules_of(analyze_one(CORE, "NEW_FOLD = 0xBEEF\n"),
                    "fold-drift")
    assert len(hits) == 1 and "not ledgered" in hits[0].message


def test_fold_unregistered_fires_on_raw_separator_literal():
    src = "import jax\nk = jax.random.fold_in(key, 0x0FAD)\n"
    hits = rules_of(analyze_one(CORE, src), "fold-unregistered")
    assert len(hits) == 1 and hits[0].line == 2
    assert "0xfad" in hits[0].message


def test_fold_rules_pass_on_registered_and_index_folds():
    src = ("SR_FOLD = 0x5A8\n"
           "k = jax.random.fold_in(key, SR_FOLD)\n"
           "ks = [jax.random.fold_in(key, i) for i in range(4)]\n"
           "k2 = jax.random.fold_in(key, 3)\n")   # index fold, exempt
    findings = analyze_one(CORE, src)
    assert not [f for f in findings if f.rule.startswith("fold-")]


def test_registry_coverage_flags_stale_entry():
    hits = rules_of(analyze_one(CORE, "SR_FOLD = 0x5A8\n",
                                check_registry_coverage=True),
                    "fold-drift")
    assert len(hits) == 1 and "DL_FOLD" in hits[0].message


# ---------------------------------------------------------------------------
# rekey-in-round


REKEY_SRC = ("import jax\n"
             "def round_body(key):\n"
             "    k1, k2 = jax.random.split(key)\n"
             "    fresh = jax.random.PRNGKey(0)\n"
             "    return k1, k2, fresh\n")


def test_rekey_fires_inside_round_scope():
    hits = rules_of(analyze_one(CORE, REKEY_SRC), "rekey-in-round")
    assert {(f.line, f.severity) for f in hits} == {(3, "warn"),
                                                   (4, "error")}


def test_rekey_scoped_to_round_modules_and_waivable():
    assert not rules_of(analyze_one(OTHER, REKEY_SRC), "rekey-in-round")
    waived = REKEY_SRC.replace(
        "split(key)",
        "split(key)  # repro-lint: allow[rekey-in-round]")
    hits = rules_of(analyze_one(CORE, waived), "rekey-in-round")
    assert [f.line for f in hits] == [4]   # only the un-waived mint


def test_rekey_ignores_module_level_calls():
    src = "import jax\nk1, k2 = jax.random.split(jax.random.key(0))\n"
    assert not rules_of(analyze_one(CORE, src), "rekey-in-round")


# ---------------------------------------------------------------------------
# zero-tail-restore


STRIPPED = ("def aggregate(payload, scales, u, e, zero_fold):\n"
            "    y = ota_receive_slab(payload, scales, u, e,\n"
            "                         alpha=1.5, scale=0.1,\n"
            "                         packed='sign' if zero_fold else None)\n"
            "    return y\n")


def test_zero_tail_fires_on_stripped_restore():
    hits = rules_of(analyze_one(CORE, STRIPPED), "zero-tail-restore")
    assert len(hits) == 1
    assert hits[0].line == 2 and hits[0].severity == "error"
    assert "restore_zero_tail" in hits[0].message


def test_zero_tail_passes_when_restored_or_not_reachable():
    restored = STRIPPED.replace(
        "    return y\n",
        "    y = restore_zero_tail(y, d, zero_fold)\n    return y\n")
    assert not rules_of(analyze_one(CORE, restored), "zero-tail-restore")
    no_zero_fold = ("def aggregate(payload, scales, u, e):\n"
                    "    return ota_receive_slab(payload, scales, u, e,\n"
                    "                            alpha=1.5, scale=0.1)\n")
    assert not rules_of(analyze_one(CORE, no_zero_fold),
                        "zero-tail-restore")
    # out of scope: kernels define the receive, core modules consume it
    assert not rules_of(analyze_one(KERNEL, STRIPPED),
                        "zero-tail-restore")


# ---------------------------------------------------------------------------
# kernel-mirror


KERNEL_SRC = ("import jax.experimental.pallas as pl\n"
              "def foo_slab(x, y, *, alpha, block_cols=128,\n"
              "             interpret=None):\n"
              "    return pl.pallas_call(None)(x, y)\n"
              "def _helper(x):\n"
              "    return pl.pallas_call(None)(x)\n")


def test_kernel_mirror_fires_on_missing_oracle():
    hits = rules_of(analyze_sources({KERNEL: KERNEL_SRC,
                                     REF: "def bar_ref(x):\n    pass\n"},
                                    registry=REG), "kernel-mirror")
    assert len(hits) == 1   # _helper is private: skipped
    assert "foo_ref" in hits[0].message and hits[0].severity == "error"


def test_kernel_mirror_fires_on_signature_mismatch():
    ref = "def foo_ref(x, y, *, beta):\n    pass\n"
    hits = rules_of(analyze_sources({KERNEL: KERNEL_SRC, REF: ref},
                                    registry=REG), "kernel-mirror")
    assert len(hits) == 1
    assert "missing ['alpha']" in hits[0].message
    assert "extra ['beta']" in hits[0].message


def test_kernel_mirror_passes_modulo_launch_params():
    ref = "def foo_ref(x, y, *, alpha):\n    pass\n"
    assert not rules_of(analyze_sources({KERNEL: KERNEL_SRC, REF: ref},
                                        registry=REG), "kernel-mirror")


def test_kernel_mirror_fires_on_operand_order_swap():
    ref = "def foo_ref(y, x, *, alpha):\n    pass\n"
    hits = rules_of(analyze_sources({KERNEL: KERNEL_SRC, REF: ref},
                                    registry=REG), "kernel-mirror")
    assert len(hits) == 1 and "positional" in hits[0].message


# ---------------------------------------------------------------------------
# local-import


def test_local_import_fires_without_waiver():
    src = "def f():\n    import math\n    return math.pi\n"
    hits = rules_of(analyze_one(OTHER, src), "local-import")
    assert len(hits) == 1 and hits[0].line == 2


def test_local_import_honours_waiver_and_guards():
    src = ("from typing import TYPE_CHECKING\n"
           "if TYPE_CHECKING:\n"
           "    from foo import Bar\n"
           "try:\n"
           "    import fancy\n"
           "except ImportError:\n"
           "    fancy = None\n"
           "def f():\n"
           "    # repro-lint: lazy-import (cycle: test fixture)\n"
           "    from repro.core import fl\n"
           "    return fl\n")
    assert not rules_of(analyze_one(OTHER, src), "local-import")


def test_syntax_error_is_a_finding_not_a_crash():
    hits = rules_of(analyze_one(OTHER, "def f(:\n"), "syntax-error")
    assert len(hits) == 1 and hits[0].severity == "error"


# ---------------------------------------------------------------------------
# findings + baseline workflow


def test_finding_render_format():
    f = Finding("src/repro/core/ota.py", 12, "fold-drift", "error",
                "boom", snippet="X_FOLD = 1")
    assert f.render() == ("src/repro/core/ota.py:12 fold-drift "
                          "[error] boom")


def test_baseline_absorbs_by_snippet_not_line(tmp_path):
    old = [Finding(CORE, 10, "rekey-in-round", "warn", "m",
                   snippet="k1, k2 = jax.random.split(key)")]
    path = str(tmp_path / "base.json")
    write_baseline(path, old)
    # same finding drifted to another line: still baselined
    drifted = [Finding(CORE, 99, "rekey-in-round", "warn", "m",
                       snippet="k1, k2 = jax.random.split(key)")]
    assert new_findings(drifted, load_baseline(path)) == []
    # a SECOND occurrence of the same line is new
    two = drifted + [Finding(CORE, 120, "rekey-in-round", "warn", "m",
                             snippet="k1, k2 = jax.random.split(key)")]
    assert len(new_findings(two, load_baseline(path))) == 1


def test_baseline_version_mismatch_rejected(tmp_path):
    path = tmp_path / "base.json"
    path.write_text(json.dumps({"version": 99, "findings": {}}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(str(path))


# ---------------------------------------------------------------------------
# the live repo


def test_live_repo_clean_against_committed_baseline():
    """The tree as committed has no findings beyond the baseline —
    the same gate CI runs."""
    findings = analyze_repo(ROOT)
    baseline = load_baseline(str(ROOT / ".repro-lint-baseline.json"))
    fresh = new_findings(findings, baseline)
    assert fresh == [], "\n".join(f.render() for f in fresh)


def test_cli_exit_codes(tmp_path):
    env_cmd = [sys.executable, "-m", "repro.analysis", "--root",
               str(ROOT)]
    r = subprocess.run(env_cmd, capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    # without the baseline the accepted rekey warns resurface -> exit 1
    r = subprocess.run(env_cmd + ["--no-baseline"], capture_output=True,
                       text=True)
    assert r.returncode == 1
    assert "rekey-in-round" in r.stdout
    # --write-baseline to a scratch path round-trips to exit 0
    scratch = str(tmp_path / "b.json")
    r = subprocess.run(env_cmd + ["--write-baseline", "--baseline",
                                  scratch], capture_output=True,
                       text=True)
    assert r.returncode == 0
    r = subprocess.run(env_cmd + ["--baseline", scratch],
                       capture_output=True, text=True)
    assert r.returncode == 0


# ---------------------------------------------------------------------------
# jaxpr tier


def test_prng_ledger_detects_forked_draws():
    """The mechanics: two traced functions that draw differently have
    different ledgers; identical draw plans have equal ledgers."""
    import jax
    from repro.analysis.jaxpr_checks import prng_ledger

    def one_draw(key):
        return jax.random.uniform(key, (8,))

    def forked(key):
        k1, k2 = jax.random.split(key)
        return jax.random.uniform(k1, (8,)) + jax.random.uniform(k2, (8,))

    def one_draw_sliced(key):
        u = jax.random.uniform(key, (8,))
        return u[:4] + u[4:]

    key = jax.random.key(0)
    base = prng_ledger(one_draw, key)
    assert sum(base.values()) == 1
    assert prng_ledger(forked, key) != base
    assert prng_ledger(one_draw_sliced, key) == base


def test_downcast_ledger_detects_narrowing():
    import jax.numpy as jnp
    from repro.analysis.jaxpr_checks import downcast_ledger

    def narrowing(x):
        return x.astype(jnp.int8).astype(jnp.float32)

    def clean(x):
        return x.astype(jnp.float64) if False else x * 2

    x = jnp.ones((4,), jnp.float32)
    assert downcast_ledger(narrowing, x) == {"int8": 1}
    assert not downcast_ledger(clean, x)


def test_prng_ledger_mismatch_is_reported_with_location(monkeypatch):
    """When a backend's draw plan forks, check_prng_ledger emits a
    finding with the anchor file, the prng-ledger rule id, and the
    offending backend in the message."""
    import jax
    from repro.analysis import jaxpr_checks

    def one_draw(key):
        return jax.random.uniform(key, (8,))

    def forked(key):
        k1, k2 = jax.random.split(key)
        return jax.random.uniform(k1, (8,)) + jax.random.uniform(k2, (8,))

    key = jax.random.key(0)
    # Stub cells with the real (step, state, key, batches) shape; the
    # unmodified prng_ledger/check_prng_ledger path runs end to end.
    monkeypatch.setattr(
        jaxpr_checks, "_backend_cells",
        lambda: [("jnp", (lambda s, k, b: one_draw(k), 0.0, key, 0.0)),
                 ("pallas", (lambda s, k, b: forked(k), 0.0, key, 0.0))])
    hits = jaxpr_checks.check_prng_ledger()
    assert len(hits) == 1
    f = hits[0]
    assert f.rule == "prng-ledger" and f.severity == "error"
    assert f.file == "src/repro/core/fl.py" and f.line == 1
    assert "pallas" in f.message and "x1 vs pallas x2" in f.message
    assert f.render().startswith("src/repro/core/fl.py:1 prng-ledger")


def test_engine_prng_ledger_identical_across_backends():
    """The real contract: jnp / pallas / pallas_sharded round steps
    consume identical randomness on the tiny f32 cell."""
    from repro.analysis.jaxpr_checks import (_backend_cells,
                                             check_prng_ledger,
                                             prng_ledger)
    ledgers = {name: prng_ledger(step, st, key, b)
               for name, (step, st, key, b) in _backend_cells()}
    assert sum(ledgers["jnp"].values()) > 0
    assert ledgers["pallas"] == ledgers["jnp"]
    assert ledgers["pallas_sharded"] == ledgers["jnp"]
    assert check_prng_ledger() == []


def test_engine_f32_cell_has_no_wire_downcast():
    from repro.analysis.jaxpr_checks import check_wire_downcast
    assert check_wire_downcast() == []


def test_engine_donation_fully_aliased():
    from repro.analysis.jaxpr_checks import check_donation
    assert check_donation() == []
