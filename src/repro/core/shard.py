"""Sharded slab engine: the OTA round distributed over a device mesh.

The paper's aggregation (Eq. 7) is a *physical superposition*: all N
clients transmit simultaneously and the channel adds their signals.
``shard_round_step`` maps that superposition onto a device mesh — the
mesh IS the multiple-access channel:

1. The mesh's client-carrying axes (every axis except ``"model"``) are
   split into P shard groups; each holds N/P clients and computes their
   gradients locally (the client compute is embarrassingly parallel).
2. Each device runs ONE fused ``ota_channel_slab`` launch over its local
   client rows — the faded partial sum ``(1/N) sum_{n local} h_n G_n``
   over the full slab width — and a cross-client ``psum`` completes the
   MAC exactly like the over-the-air sum.
3. The interference xi_t is added once, from the SAME per-leaf CMS draws
   the single-device backends consume (see the PRNG contract below).
4. Each device then owns one contiguous, lane-aligned slice of the slab
   (the shard-aligned padding rule of ``make_slab_spec(..., shards=P)``)
   and runs ONE fused ``adaptive_update_slab`` launch on its slice —
   the server update is model-sharded, ZeRO-style. The updated slices
   are regathered (masked psum) so params/state come back as full
   pytrees, drop-in interchangeable with the other backends.

**Per-shard PRNG keying contract.** Every random draw is made from the
round key with the exact keying of the single-device path and then
*sliced*, never re-keyed per shard:

* fading: ``kh, kx = split(key)``; ``h = sample_fading(kh, cfg, (N,))``
  is the full draw on every shard; shard s uses rows
  ``h[s*N/P : (s+1)*N/P]`` (clients are laid out in linear shard-index
  order, matching the batch sharding).
* interference: ``(u, e) = _cms_slab_inputs(kx, spec)`` draws per LEAF
  (``fold_in(kx, leaf_index)``), so the values of every real slab entry
  are independent of the padded length — specs built with different
  ``shards`` (hence different padding) agree on every real entry.

Hence jnp, pallas and pallas_sharded consume literally the same noise,
and differ only by float32 summation order (psum of P partial sums vs
one in-kernel reduction) — parity holds to ~1e-7 relative, tested at
1e-5 (tests/test_shard_roundstep.py, repro.launch.shard_check).
"""

from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.adaptive import (AdaptiveConfig, ServerOptState,
                                 pack_state_slabs, slab_update_slabs,
                                 unpack_state_slabs)
from repro.core.channel import OTAChannelConfig, cms_transform, sample_fading
from repro.core.fl import FLConfig, RoundMetrics, _client_update
from repro.core.ota import _cms_slab_inputs, linear_shard_index
from repro.core.slab import make_slab_spec, slab_to_tree, stack_to_slab, tree_to_slab

PyTree = Any


def client_axes_of(mesh) -> Tuple[str, ...]:
    """The client-carrying axes of a mesh: every axis except "model"."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_client_shards(mesh) -> int:
    return math.prod(mesh.shape[a] for a in client_axes_of(mesh))


def shard_round_step(loss_fn, channel_cfg: OTAChannelConfig,
                     adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig, mesh,
                     jit: bool = True):
    """Build the distributed twin of ``make_round_step(backend="pallas")``.

    Returns ``round_step(params, opt_state, key, client_batches)`` with
    the SAME signature and pytree in/out contract as the single-device
    backends: ``client_batches`` leaves carry the global client axis N
    up front and are sharded over the mesh's client axes by shard_map;
    params/opt_state go in and come out as full (replicated) pytrees.

    Per device and per round the body is exactly two fused Pallas
    launches — ``ota_channel_slab`` over the device's local client rows
    and ``adaptive_update_slab`` over its slab slice — plus two psums
    (the MAC superposition and the slice regather).
    """
    axes = client_axes_of(mesh)
    if not axes:
        raise ValueError("mesh has no client-carrying axes (all axes are "
                         "'model'); shard_round_step needs at least one")
    n_shards = n_client_shards(mesh)
    n = fl_cfg.n_clients
    if n % n_shards != 0:
        raise ValueError(
            f"n_clients={n} must be divisible by the mesh's client-shard "
            f"count {n_shards} (axes {axes} of mesh shape {dict(mesh.shape)})")
    n_local = n // n_shards
    client_fn = _client_update(loss_fn, fl_cfg)

    def body(params, opt_state: ServerOptState, key, local_batches):
        # --- local client compute: N/P clients on this device ---------
        grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(params,
                                                               local_batches)
        spec = make_slab_spec(params, shards=n_shards)
        shard_len = spec.shard_len
        idx = linear_shard_index(axes)

        # --- PRNG: full draws from the round key, sliced per shard ----
        kh, kx = jax.random.split(key)
        h = sample_fading(kh, channel_cfg, (n,))
        h_loc = jax.lax.dynamic_slice_in_dim(h, idx * n_local, n_local)

        # --- launch 1: fused partial MAC over the local client rows ---
        g_loc_stack = stack_to_slab(spec, grads)          # (n_local, padded)
        from repro.kernels.ota_channel import ota_channel_slab
        zeros = jnp.zeros((spec.padded,), jnp.float32)
        partial = ota_channel_slab(
            g_loc_stack, h_loc, zeros, jnp.ones_like(zeros),
            alpha=channel_cfg.alpha, scale=0.0, n_total=n,
            interpret=channel_cfg.interpret)
        clean_part = jnp.sum(g_loc_stack, axis=0)

        # --- the superposition: ONE cross-client psum == the MAC ------
        summed = jax.lax.psum(jnp.stack([partial, clean_part]), axes)
        g_slab, clean_sum = summed[0], summed[1]
        if channel_cfg.interference:
            # Identical draws to the single-device backends (per-leaf
            # keying is padding-independent); added once, post-psum —
            # the server's single RF front end.
            u, e = _cms_slab_inputs(kx, spec)
            g_slab = g_slab + channel_cfg.xi_scale * cms_transform(
                u, e, channel_cfg.alpha)

        # --- launch 2: fused server update on this device's slice -----
        start = idx * shard_len
        sl = lambda s: jax.lax.dynamic_slice_in_dim(s, start, shard_len)
        w_slab = tree_to_slab(spec, params)
        state_slabs = pack_state_slabs(adaptive_cfg, spec, opt_state)
        new_slices, w_slice = slab_update_slabs(
            adaptive_cfg, sl(g_slab), tuple(sl(s) for s in state_slabs),
            sl(w_slab))

        # --- regather the updated slices (masked psum == all_gather) --
        rows = jnp.stack(list(new_slices) + [w_slice])     # (k+1, shard_len)
        full = jnp.zeros((rows.shape[0], spec.padded), jnp.float32)
        full = jax.lax.psum(
            jax.lax.dynamic_update_slice(full, rows, (0, start)), axes)
        new_params = slab_to_tree(spec, full[-1])
        new_state = unpack_state_slabs(adaptive_cfg, spec, opt_state,
                                       tuple(full[:-1]))

        metrics = RoundMetrics(
            loss=jax.lax.pmean(jnp.mean(losses), axes),
            grad_norm=jnp.sqrt(jnp.sum(jnp.square(clean_sum / n))),
            noisy_grad_norm=jnp.sqrt(jnp.sum(jnp.square(g_slab))),
            fading_mean=jnp.mean(h),
        )
        return new_params, new_state, metrics

    step = shard_map(body, mesh,
                     in_specs=(P(), P(), P(), P(axes)),
                     out_specs=(P(), P(), P()))
    return jax.jit(step) if jit else step
