"""Federated-learning round orchestration (paper Algorithm 1).

``make_round_step`` builds a jit-compiled function executing one full
communication round of ADOTA-FL in *simulation* mode (all N clients on
this host, vmapped):

    1. CLIENTUPDATE: every client computes its local gradient (k = 1,
       the paper's algorithm) or a FedAvg-style pseudo-gradient from k
       local SGD steps (optional extension);
    2. the analog MAC aggregates: g_t = (1/N) sum_n h_n grad_n + xi_t;
    3. the server applies the ADOTA adaptive update.

Steps 2-3 run on one of two backends. ``backend="jnp"`` is the per-leaf
``tree.map`` reference. ``backend="pallas"`` is the slab engine: client
gradients are stacked into one (N, d) slab (``repro.core.slab``), the
MAC is ONE fused ``ota_channel_slab`` launch, the resulting g_t slab is
fed — still flat — into ONE fused ``adaptive_update_slab`` launch, and
only then are params/state restored to pytrees. Two kernel launches per
round over the whole model instead of dozens of per-leaf ops; results
match the jnp backend to f32 rounding (both backends consume identical
PRNG draws).

``backend="pallas_sharded"`` (requires ``mesh=``) is the distributed
slab engine (``repro.core.shard.shard_round_step``): the client axis and
the slab are partitioned over the mesh's client-carrying axes, each
device runs the two fused launches on its local clients/slab shard, and
the OTA superposition is a real cross-client collective.

Every backend routes the MAC through the staged uplink pipeline
(``OTAChannelConfig.uplink``, see ``repro.core.ota``): transmit power
control -> quantize -> superposition -> interference -> receiver
dequantize. At the default ``uplink="f32"`` the rounds are
bitwise-identical to the pre-pipeline code; ``uplink="int8"`` carries
int8 payloads + per-block f32 scales over the MAC (~4x fewer collective
bytes on the sharded mesh); ``uplink="sign"`` carries 1-bit signSGD
payloads (~32x). The quantized modes optionally carry a per-transmitter
error-feedback residual across rounds (``UplinkConfig.error_feedback``,
resident as ``SlabTrainState.ef``), and the per-round model broadcast
can itself be int8-quantized (``OTAChannelConfig.downlink="int8"`` —
clients see the reconstruction, the server keeps the f32 master). Both
live only in the slab-resident loops.

``make_sharded_round_step`` is the older per-leaf distributed twin:
clients map onto (pod, data) shard groups and step 2 becomes the
``ota_psum`` collective inside ``shard_map``.

**Slab-resident variants** (the multi-round hot path since PR 3):
``make_slab_round_step`` / ``make_slab_round_runner`` keep the training
state as a ``SlabTrainState`` — params slab + optimizer-state slabs —
ACROSS rounds, materialising pytrees only at boundaries (init, eval,
checkpoint). The runner drives R rounds as one ``jax.lax.scan`` over
the resident state (under a mesh: scan inside ``shard_map``, each
device carrying only its slab slices — no full-model regather in the
scanned body). ``run_rounds_slab`` is the host driver twin of
``run_rounds`` with identical PRNG keying, so both drivers produce the
same trajectory from the same key.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptive import (AdaptiveConfig, ServerOptState,
                                 apply_slab_update, make_server_optimizer,
                                 slab_update_slabs)
from repro.core.channel import OTAChannelConfig
from repro.core.ota import (downlink_quantize_slab, downlink_sr_slab_inputs,
                            ota_aggregate_slab, ota_aggregate_stacked,
                            ota_psum)
from repro.core.slab import make_slab_spec, slab_to_tree, tree_to_slab
from repro.core.slab_state import (SlabTrainState, pack_train_state,
                                   unpack_train_state)
from repro.core.stream import streamed_round_parts
from repro.core.tail_index import effective_alpha, update_alpha_ema

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]   # (params, batch) -> scalar


@dataclasses.dataclass(frozen=True)
class FLConfig:
    n_clients: int = 50
    local_steps: int = 1          # k; 1 == Algorithm 1 (one grad per round)
    local_lr: float = 0.05        # local SGD lr when local_steps > 1
    # Streamed client axis (PR 6, repro.core.stream): chunk the client
    # rows through the accumulating transmit kernel so peak memory is
    # O(client_chunk * d) regardless of n_clients. None == resident
    # (all rows in one chunk; the bitwise-parity configuration).
    client_chunk: Optional[int] = None
    # Partial participation: each client joins this round i.i.d. with
    # this probability (mask keyed off the round key, identical on all
    # backends). 1.0 == everyone, the pre-sampling bitwise path. Must
    # be > 0: rate 0 would make EVERY round a dead round (nobody ever
    # transmits, the state never moves), which is a config error, not
    # a training run.
    sample_rate: float = 1.0
    # Per-client aggregation weights (e.g. dataset sizes); None ==
    # uniform. The noisy aggregate is sum_n mask_n w_n h_n g_n
    # normalised by sum_n mask_n w_n, so any uniform tuple (c, ..., c)
    # reduces to the 1/N path.
    client_weights: Optional[Tuple[float, ...]] = None
    # Double-buffered streaming (PR 9): the client scan carries a
    # two-slot pipeline — chunk c's gradients are computed while chunk
    # c-1's prefetched slot is folded into the accumulators in one
    # fused pass — so the accumulation of one chunk overlaps the
    # compute of the next. Same draws, same chunk schedule; the fold
    # reassociates the per-chunk reduction, so the double-buffered
    # round is held to the loose cross-engine tolerance tier, not the
    # bitwise one (default off == today's serial scan, bit for bit).
    # Requires client_chunk (there is no scan to pipeline without it).
    double_buffer: bool = False

    def __post_init__(self):
        if not 0.0 < self.sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in (0, 1], got "
                             f"{self.sample_rate}; a rate of 0 means no "
                             "client ever participates (every round would "
                             "be a dead round)")
        if self.client_chunk is not None and self.client_chunk < 1:
            raise ValueError(f"client_chunk must be >= 1, got "
                             f"{self.client_chunk}")
        if self.double_buffer and self.client_chunk is None:
            raise ValueError(
                "double_buffer pipelines the STREAMED client scan; set "
                "client_chunk (the resident round has no chunk schedule "
                "to double-buffer)")
        if self.client_weights is not None:
            w = tuple(float(x) for x in self.client_weights)
            if len(w) != self.n_clients:
                raise ValueError(f"client_weights must have one entry per "
                                 f"client: got {len(w)} for "
                                 f"{self.n_clients} clients")
            if not all(math.isfinite(x) and x >= 0.0 for x in w):
                raise ValueError("client_weights must be finite and >= 0")
            if sum(w) <= 0.0:
                raise ValueError("client_weights must sum to > 0")
            object.__setattr__(self, "client_weights", w)

    @property
    def dynamic_norm(self) -> bool:
        """True when the aggregate normaliser is a round-dependent
        value (sum of participating weights) instead of the static 1/N."""
        return self.sample_rate < 1.0 or self.client_weights is not None

    @property
    def dynamic_round(self) -> bool:
        """True when the round must take the streamed/participating
        path (repro.core.stream) instead of the resident one."""
        return self.client_chunk is not None or self.dynamic_norm


class RoundMetrics(NamedTuple):
    loss: jax.Array               # mean participating-client loss before
                                  # the update
    grad_norm: jax.Array          # L2 norm of the clean aggregated gradient
    noisy_grad_norm: jax.Array    # L2 norm of g_t after the channel
    fading_mean: jax.Array        # mean of this round's h draw
    alpha_hat: jax.Array          # the tail index the server update used:
                                  # the resident EMA of the fused log-moment
                                  # estimate under alpha == "auto" (0.0
                                  # until first seeded), else the static
                                  # config float
    n_participants: jax.Array     # f32 count of clients in this round's
                                  # aggregate (== n_clients without
                                  # sampling; 0.0 marks a skipped round)


def _tree_l2(t: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(t)))


def _log_round(log, t: int, rec: dict) -> None:
    """One history record, formatted identically by both drivers."""
    log(f"round {t+1:5d}  loss {rec['loss']:.4f}  "
        f"|g| {rec['grad_norm']:.3e}  |g_t| {rec['noisy_grad_norm']:.3e}"
        + (f"  acc {rec.get('accuracy', float('nan')):.4f}"
           if 'accuracy' in rec else ""))


def _client_update(loss_fn: LossFn, fl_cfg: FLConfig
                   ) -> Callable[[PyTree, Any], Tuple[PyTree, jax.Array]]:
    """Build CLIENTUPDATE: (params, client_batch) -> (grad-like, loss)."""

    if fl_cfg.local_steps == 1:
        def one(params, batch):
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
            return g, loss
        return one

    def multi(params, batches):
        # batches: pytree with leading axis k (one micro-batch per step).
        def step(w, batch):
            loss, g = jax.value_and_grad(loss_fn)(w, batch)
            w = jax.tree.map(lambda p, gi: p - fl_cfg.local_lr * gi, w, g)
            return w, loss
        w_k, losses = jax.lax.scan(step, params, batches)
        denom = fl_cfg.local_lr * fl_cfg.local_steps
        pseudo = jax.tree.map(lambda a, b: (a - b) / denom, params, w_k)
        # Mean over the k local steps, so RoundMetrics.loss is comparable
        # between local_steps == 1 and > 1 (losses[0] alone would report
        # only the pre-update loss of the first micro-batch).
        return pseudo, jnp.mean(losses)

    return multi


def _resolve_backend(backend: Optional[str], channel_cfg: OTAChannelConfig,
                     adaptive_cfg: AdaptiveConfig
                     ) -> Tuple[str, OTAChannelConfig, AdaptiveConfig]:
    """Pick the round backend and force both configs onto it.

    An explicit ``backend`` argument wins; otherwise the "biggest"
    backend either config requests switches the whole round (a split
    round — slab MAC but tree.map update, or vice versa — would just pay
    both conversion costs)."""
    if backend is None:
        requested = (channel_cfg.backend, adaptive_cfg.backend)
        backend = "jnp"
        for cand in ("pallas", "pallas_sharded"):
            if cand in requested:
                backend = cand
    if backend not in ("jnp", "pallas", "pallas_sharded"):
        raise ValueError(f"unknown round backend: {backend}")
    channel_cfg = dataclasses.replace(channel_cfg, backend=backend)
    adaptive_cfg = dataclasses.replace(adaptive_cfg, backend=backend)
    return backend, channel_cfg, adaptive_cfg


def make_round_step(loss_fn: LossFn, channel_cfg: OTAChannelConfig,
                    adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig,
                    jit: bool = True, backend: Optional[str] = None,
                    mesh=None):
    """One ADOTA-FL round over vmapped clients.

    Returns ``round_step(params, opt_state, key, client_batches)`` where
    ``client_batches`` leaves have shape (N, ...) for local_steps == 1 and
    (N, k, ...) otherwise. ``backend`` overrides the configs' backend
    fields ("jnp" | "pallas" | "pallas_sharded"); with "pallas" the round
    executes exactly one ``ota_channel_slab`` and one
    ``adaptive_update_slab`` launch over the full model. With
    "pallas_sharded" the round is distributed over ``mesh``'s
    client-carrying axes (required argument then): same signature, same
    results to f32 rounding, but each device runs the two fused launches
    on its local clients/slab shard (see ``repro.core.shard``).
    """
    backend, channel_cfg, adaptive_cfg = _resolve_backend(
        backend, channel_cfg, adaptive_cfg)
    if adaptive_cfg.track_alpha:
        raise ValueError(
            'AdaptiveConfig.alpha == "auto" needs the slab-resident loop '
            '(make_slab_round_step / make_slab_round_runner, or '
            'launch.train --track-alpha): the per-round pytree API has no '
            'resident alpha_hat to carry the estimator EMA across rounds')
    if fl_cfg.dynamic_round:
        raise ValueError(
            "client_chunk / sample_rate < 1 / client_weights need the "
            "slab-resident loop (make_slab_round_step / "
            "make_slab_round_runner): the per-round pytree API has no "
            "streamed uplink path")
    if channel_cfg.uplink.error_feedback or channel_cfg.downlink != "f32":
        raise ValueError(
            "error_feedback / downlink != \"f32\" need the slab-resident "
            "loop (make_slab_round_step / make_slab_round_runner): the "
            "per-round pytree API has no resident residual slab to carry "
            "across rounds and no slab broadcast to quantize")
    alpha_const = jnp.asarray(adaptive_cfg.alpha, jnp.float32)
    if backend == "pallas_sharded":
        # repro-lint: lazy-import (cycle: core.shard imports core.fl)
        from repro.core.shard import shard_round_step
        if mesh is None:
            raise ValueError('backend="pallas_sharded" needs a mesh; pass '
                             'make_round_step(..., mesh=...)')
        return shard_round_step(loss_fn, channel_cfg, adaptive_cfg, fl_cfg,
                                mesh, jit=jit)
    if mesh is not None:
        raise ValueError(
            f'mesh= was given but the resolved backend is "{backend}", '
            'which runs single-device and would silently ignore it; use '
            'backend="pallas_sharded" for distributed rounds')
    server_opt = make_server_optimizer(adaptive_cfg)
    client_fn = _client_update(loss_fn, fl_cfg)

    def round_step_jnp(params, opt_state: ServerOptState, key, client_batches):
        grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(params, client_batches)
        g_t, h = ota_aggregate_stacked(key, channel_cfg, grads)
        clean = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
        new_params, new_state = server_opt.update(g_t, opt_state, params)
        metrics = RoundMetrics(
            loss=jnp.mean(losses),
            grad_norm=_tree_l2(clean),
            noisy_grad_norm=_tree_l2(g_t),
            fading_mean=jnp.mean(h),
            alpha_hat=alpha_const,
            n_participants=jnp.asarray(float(fl_cfg.n_clients), jnp.float32),
        )
        return new_params, new_state, metrics

    def round_step_slab(params, opt_state: ServerOptState, key, client_batches):
        grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(params, client_batches)
        spec = make_slab_spec(params)
        # Kernel launch 1: fused fading reduction + interference synthesis.
        g_slab, h, grads_slab, _, _ = ota_aggregate_slab(key, channel_cfg,
                                                         grads, spec)
        # Kernel launch 2: fused server update, g_t still in slab form.
        new_params, new_state = apply_slab_update(adaptive_cfg, spec, g_slab,
                                                  opt_state, params)
        # Slab norms == tree norms: the padding tail is zero by contract.
        metrics = RoundMetrics(
            loss=jnp.mean(losses),
            grad_norm=jnp.sqrt(jnp.sum(jnp.square(
                jnp.mean(grads_slab, axis=0)))),
            noisy_grad_norm=jnp.sqrt(jnp.sum(jnp.square(g_slab))),
            fading_mean=jnp.mean(h),
            alpha_hat=alpha_const,
            n_participants=jnp.asarray(float(fl_cfg.n_clients), jnp.float32),
        )
        return new_params, new_state, metrics

    round_step = round_step_slab if backend == "pallas" else round_step_jnp
    return jax.jit(round_step) if jit else round_step


def init_server(params: PyTree, adaptive_cfg: AdaptiveConfig) -> ServerOptState:
    return make_server_optimizer(adaptive_cfg).init(params)


# ---------------------------------------------------------------------------
# Slab-resident variants: state stays a SlabTrainState across rounds.
# ---------------------------------------------------------------------------

def make_slab_round_step(loss_fn: LossFn, channel_cfg: OTAChannelConfig,
                         adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig,
                         jit: bool = True, backend: Optional[str] = None,
                         mesh=None, batch_gen=None):
    """Slab-state twin of ``make_round_step``.

    Returns ``step(state, key, client_batches) -> (state, metrics)``
    where ``state`` is a ``SlabTrainState`` (see
    ``repro.core.slab_state``). Per round the only pytree materialised
    is the parameter view the clients consume (the server's model
    broadcast); optimizer state never leaves slab form. Backends:

    * ``"pallas"`` — resident single-device slab engine: two fused
      kernel launches per round, zero pack/unpack passes in steady
      state (vs 2 full packs + 2k slab round-trips for the pytree API).
    * ``"pallas_sharded"`` (requires ``mesh=``) — each device keeps only
      its slab slices; see ``repro.core.shard.make_shard_slab_step``.
    * ``"jnp"`` — reference: materialises pytrees each round and runs
      the per-leaf update (boundary conversion per round — the parity
      oracle, not a fast path).

    All backends consume identical PRNG draws, so their multi-round
    trajectories agree to f32 rounding.

    A DYNAMIC round config (``fl_cfg.client_chunk`` / ``sample_rate``
    / ``client_weights``) routes the jnp and pallas backends through
    the streamed uplink (``repro.core.stream``): the client axis is
    scanned in O(client_chunk * d) memory, participation and weights
    fold into the effective fading, and a zero-participation round
    SKIPS the server update (state unchanged, metrics recorded with
    ``n_participants == 0``). ``batch_gen(key, idx)`` replaces
    materialised ``client_batches`` (pass None for them then) with
    in-graph batch synthesis for populations too large to hold — only
    the streamed single-device backends support it.
    """
    backend, channel_cfg, adaptive_cfg = _resolve_backend(
        backend, channel_cfg, adaptive_cfg)
    if backend == "pallas_sharded":
        # repro-lint: lazy-import (cycle: core.shard imports core.fl)
        from repro.core.shard import make_shard_slab_step
        if mesh is None:
            raise ValueError('backend="pallas_sharded" needs a mesh; pass '
                             'make_slab_round_step(..., mesh=...)')
        if batch_gen is not None:
            raise ValueError('batch_gen= is only supported by the streamed '
                             'single-device backends, not "pallas_sharded"')
        return make_shard_slab_step(loss_fn, channel_cfg, adaptive_cfg,
                                    fl_cfg, mesh, jit=jit)
    if mesh is not None:
        raise ValueError(
            f'mesh= was given but the resolved backend is "{backend}", '
            'which runs single-device and would silently ignore it; use '
            'backend="pallas_sharded" for distributed rounds')
    track = adaptive_cfg.track_alpha
    # PR 7 wire formats: error feedback carries a resident residual slab
    # (SlabTrainState.ef) and the int8 downlink quantizes the model
    # broadcast — both live only in the slab-resident loops. On the jnp
    # backend they bypass the pytree-delegation reference paths below
    # and take the generic slab step (whose MAC/update layers dispatch
    # to the kernels.ref oracles internally), so every backend runs the
    # same EF/downlink plumbing over the same draws.
    use_ef = channel_cfg.uplink.error_feedback
    dl_int8 = channel_cfg.downlink == "int8"
    client_fn = _client_update(loss_fn, fl_cfg)

    def _check_ef_state(state: SlabTrainState) -> None:
        if use_ef and state.ef is None:
            raise ValueError(
                "UplinkConfig.error_feedback=True but the SlabTrainState "
                "carries no residual rows; build it with "
                "init_train_state(..., error_feedback=True)")

    def _broadcast_slab(state: SlabTrainState, key):
        """The (padded,) weight slab the CLIENTS see this round: the f32
        master under the f32 downlink, its int8-quantized reconstruction
        under downlink="int8" (the server always keeps the master)."""
        if not dl_int8:
            return state.w
        r = downlink_sr_slab_inputs(key, state.spec.padded)
        return downlink_quantize_slab(state.w, r)

    if fl_cfg.dynamic_round:
        use_kernels = backend != "jnp"

        def step(state: SlabTrainState, key, client_batches=None):
            _check_ef_state(state)
            spec = state.spec
            params = slab_to_tree(spec, _broadcast_slab(state, key))
            parts = streamed_round_parts(
                key, channel_cfg, fl_cfg, spec, client_fn, params,
                client_batches=client_batches, batch_gen=batch_gen,
                pilot_stats=track, use_kernels=use_kernels,
                ef=state.ef[0] if use_ef else None)
            # Zero-participation skip: nobody transmitted, so there is
            # no aggregate to apply — the server state carries over
            # unchanged (only the round counter advances) and the
            # metrics record the dead round. Only a dynamic normaliser
            # can produce a dead round; with the static 1/N divisor the
            # selects are omitted entirely (a dead ``where`` changes
            # how XLA fuses the update kernel, costing the chunk >= N
            # bitwise contract).
            can_skip = fl_cfg.dynamic_norm
            participated = parts.norm > 0.0
            if track:
                a_new = update_alpha_ema(state.alpha_hat, parts.stats,
                                         adaptive_cfg.alpha_ema)
                alpha_hat = (jnp.where(participated, a_new, state.alpha_hat)
                             if can_skip else a_new)
                alpha_arg = effective_alpha(alpha_hat)
                alpha_metric = alpha_hat
            else:
                alpha_hat = state.alpha_hat
                alpha_arg = None
                alpha_metric = jnp.asarray(adaptive_cfg.alpha, jnp.float32)
            w_in = state.w
            if any(dt != jnp.float32 for dt in spec.dtypes):
                # The round-trip mirrors the pytree backends' per-round
                # storage-dtype cast; under the int8 downlink the cast
                # still applies to the MASTER weights (the update never
                # consumes the quantized broadcast).
                w_in = tree_to_slab(spec, params if not dl_int8
                                    else slab_to_tree(spec, state.w))
            new_opt, w_new = slab_update_slabs(adaptive_cfg, parts.g_slab,
                                               state.opt, w_in,
                                               alpha=alpha_arg)
            ef_next = parts.ef_new[None] if use_ef else state.ef
            if can_skip:
                w_new = jnp.where(participated, w_new, state.w)
                new_opt = tuple(jnp.where(participated, o_n, o_o)
                                for o_n, o_o in zip(new_opt, state.opt))
                if use_ef:
                    # A dead round transmits nothing: the residual of a
                    # transmission that never happened must not replace
                    # the carried one.
                    ef_next = jnp.where(participated, ef_next, state.ef)
            nf = jnp.maximum(parts.n_participants, 1.0)
            metrics = RoundMetrics(
                loss=parts.loss_sum / nf,
                grad_norm=jnp.sqrt(jnp.sum(jnp.square(
                    parts.clean_slab / nf))),
                noisy_grad_norm=jnp.sqrt(jnp.sum(jnp.square(parts.g_slab))),
                fading_mean=jnp.mean(parts.h),
                alpha_hat=alpha_metric,
                n_participants=parts.n_participants,
            )
            return SlabTrainState(state.step + 1, w_new, new_opt, alpha_hat,
                                  spec, ef_next), metrics

        return jax.jit(step) if jit else step

    if batch_gen is not None:
        raise ValueError("batch_gen= needs a streamed round config "
                         "(FLConfig.client_chunk); the resident path "
                         "consumes materialised client_batches")
    # EF / int8 downlink on the jnp backend skip the pytree-delegation
    # references (which have no residual slab to carry) and fall through
    # to the generic slab step; ota_aggregate_slab dispatches its MAC to
    # the kernels.ref oracles there, so it is still a pure-jnp program.
    if backend == "jnp" and not (use_ef or dl_int8):
        if not track:
            inner = make_round_step(loss_fn, channel_cfg, adaptive_cfg,
                                    fl_cfg, jit=False, backend="jnp")

            def step(state: SlabTrainState, key, client_batches):
                params, opt_state = unpack_train_state(adaptive_cfg, state)
                p, s, m = inner(params, opt_state, key, client_batches)
                return pack_train_state(adaptive_cfg, state.spec, p, s,
                                        alpha_hat=state.alpha_hat), m

            return jax.jit(step) if jit else step

        # The tracked jnp reference: the per-leaf round with the closed
        # alpha loop — stats from the per-leaf mirror of the kernel
        # epilogues, the same resident EMA, the per-leaf update consuming
        # the tracked alpha as a traced scalar. This is the parity oracle
        # the tracked pallas/pallas_sharded engines are tested against.
        server_opt = make_server_optimizer(adaptive_cfg)

        def step(state: SlabTrainState, key, client_batches):
            params, opt_state = unpack_train_state(adaptive_cfg, state)
            grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(
                params, client_batches)
            g_t, h, stats = ota_aggregate_stacked(key, channel_cfg, grads,
                                                  pilot_stats=True)
            alpha_hat = update_alpha_ema(state.alpha_hat, stats,
                                         adaptive_cfg.alpha_ema)
            clean = jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
            new_params, new_state = server_opt.update(
                g_t, opt_state, params, alpha=effective_alpha(alpha_hat))
            metrics = RoundMetrics(
                loss=jnp.mean(losses),
                grad_norm=_tree_l2(clean),
                noisy_grad_norm=_tree_l2(g_t),
                fading_mean=jnp.mean(h),
                alpha_hat=alpha_hat,
                n_participants=jnp.asarray(float(fl_cfg.n_clients),
                                           jnp.float32),
            )
            return pack_train_state(adaptive_cfg, state.spec, new_params,
                                    new_state, alpha_hat=alpha_hat), metrics

        return jax.jit(step) if jit else step

    def step(state: SlabTrainState, key, client_batches):
        _check_ef_state(state)
        spec = state.spec
        # Model broadcast: the one pytree the round materialises (the
        # clients' loss_fn consumes pytrees; original leaf dtypes).
        # Under downlink="int8" the clients see the int8-quantized
        # reconstruction; the server's master slab stays f32.
        params = slab_to_tree(spec, _broadcast_slab(state, key))
        grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(params,
                                                               client_batches)
        # Kernel launch 1: fused fading reduction + interference (with
        # the pilot-stats epilogue when the alpha loop is closed; the
        # carried EF residual joins the transmit quantizer in the same
        # launch, which returns the fresh residual to carry).
        g_slab, h, grads_slab, stats, ef_new = ota_aggregate_slab(
            key, channel_cfg, grads, spec, pilot_stats=track,
            ef=state.ef[0] if use_ef else None)
        if track:
            alpha_hat = update_alpha_ema(state.alpha_hat, stats,
                                         adaptive_cfg.alpha_ema)
            alpha_arg = effective_alpha(alpha_hat)
            alpha_metric = alpha_hat
        else:
            alpha_hat = state.alpha_hat
            alpha_arg = None
            alpha_metric = jnp.asarray(adaptive_cfg.alpha, jnp.float32)
        w_in = state.w
        if any(dt != jnp.float32 for dt in spec.dtypes):
            # Non-f32 leaves round-trip through their storage dtype each
            # round on the pytree backends; mirror that for parity. The
            # cast applies to the MASTER weights — never the quantized
            # broadcast, which only the clients consume.
            w_in = tree_to_slab(spec, params if not dl_int8
                                else slab_to_tree(spec, state.w))
        # Kernel launch 2: fused server update on the RESIDENT slabs
        # (the tracked alpha rides in as a traced operand).
        new_opt, w_new = slab_update_slabs(adaptive_cfg, g_slab, state.opt,
                                           w_in, alpha=alpha_arg)
        metrics = RoundMetrics(
            loss=jnp.mean(losses),
            grad_norm=jnp.sqrt(jnp.sum(jnp.square(
                jnp.mean(grads_slab, axis=0)))),
            noisy_grad_norm=jnp.sqrt(jnp.sum(jnp.square(g_slab))),
            fading_mean=jnp.mean(h),
            alpha_hat=alpha_metric,
            n_participants=jnp.asarray(float(fl_cfg.n_clients), jnp.float32),
        )
        return SlabTrainState(state.step + 1, w_new, new_opt, alpha_hat,
                              spec, ef_new[None] if use_ef else state.ef
                              ), metrics

    return jax.jit(step) if jit else step


def make_slab_round_runner(loss_fn: LossFn, channel_cfg: OTAChannelConfig,
                           adaptive_cfg: AdaptiveConfig, fl_cfg: FLConfig,
                           jit: bool = True, backend: Optional[str] = None,
                           mesh=None, batch_gen=None, donate: bool = False):
    """R rounds as ONE ``jax.lax.scan`` over the resident state.

    Returns ``run(state, keys, client_batches) -> (state, metrics)``
    with ``keys`` a (R,) key array and ``client_batches`` leaves shaped
    (R, N, ...); metrics come back stacked (R,). Under
    ``backend="pallas_sharded"`` the scan runs *inside* ``shard_map``
    (each device scans over its resident slices — no per-round dispatch,
    no full-model regather anywhere in the scanned body).

    With ``batch_gen(key, idx)`` (streamed in-graph data synthesis, see
    ``make_slab_round_step``) there are no materialised batches: call
    ``run(state, keys)`` and the scan carries keys only — nothing in
    the round scales with N beyond O(N) scalars (fading, mask).

    ``donate=True`` donates the incoming ``SlabTrainState`` buffers to
    the call (``donate_argnums=(0,)``): the compiled executable aliases
    every state slab (w, opt, alpha_hat, ef) to its output instead of
    allocating a second copy — the resident update is genuinely
    in-place, peak state memory is 1x across the scan-chunk boundary.
    The argument is CONSUMED: reuse of the passed state raises jax's
    donated-buffer error, so only enable it in linear state-threading
    drivers (``run_rounds_slab`` threads linearly; benches that replay
    from one initial state must not donate). Verify with
    ``donation_report``. Requires ``jit``.
    """
    backend, channel_cfg, adaptive_cfg = _resolve_backend(
        backend, channel_cfg, adaptive_cfg)
    if donate and not jit:
        raise ValueError("donate=True needs jit=True: buffer donation "
                         "is a property of the compiled executable")
    if backend == "pallas_sharded":
        # repro-lint: lazy-import (cycle: core.shard imports core.fl)
        from repro.core.shard import make_shard_slab_runner
        if mesh is None:
            raise ValueError('backend="pallas_sharded" needs a mesh; pass '
                             'make_slab_round_runner(..., mesh=...)')
        if batch_gen is not None:
            raise ValueError('batch_gen= is only supported by the streamed '
                             'single-device backends, not "pallas_sharded"')
        return make_shard_slab_runner(loss_fn, channel_cfg, adaptive_cfg,
                                      fl_cfg, mesh, jit=jit, donate=donate)
    step = make_slab_round_step(loss_fn, channel_cfg, adaptive_cfg, fl_cfg,
                                jit=False, backend=backend, mesh=mesh,
                                batch_gen=batch_gen)

    if batch_gen is not None:
        def run(state: SlabTrainState, keys, client_batches=None):
            if client_batches is not None:
                raise ValueError("batch_gen= runner takes no materialised "
                                 "client_batches")

            def scanned(s, key):
                return step(s, key)

            return jax.lax.scan(scanned, state, keys)
    else:
        def run(state: SlabTrainState, keys, client_batches):
            def scanned(s, xs):
                key, batch = xs
                return step(s, key, batch)

            return jax.lax.scan(scanned, state, (keys, client_batches))

    if not jit:
        return run
    return jax.jit(run, donate_argnums=(0,)) if donate else jax.jit(run)


def donation_report(run_jit, *example_args) -> dict:
    """Lower + compile a jitted round runner on example arguments and
    report what the executable actually aliases — the check that
    ``donate=True`` buys the in-place resident update it claims.

    Returns ``{"aliased_bytes", "donated_bytes", "aliased_pairs",
    "supported"}``: ``donated_bytes`` is the total byte size of the
    donatable state leaves (argument 0), ``aliased_bytes`` what the
    compiled memory analysis reports as input-output aliased, and
    ``aliased_pairs`` the executable's ``input_output_alias`` entries
    parsed from the HLO. On backends whose memory analysis does not
    expose aliasing, ``supported`` is False and the byte fields are
    None (callers/tests should skip, not fail).
    """
    lowered = run_jit.lower(*example_args)
    compiled = lowered.compile()
    state_leaves = jax.tree.leaves(example_args[0])
    donated = sum(x.size * x.dtype.itemsize for x in state_leaves
                  if hasattr(x, "size"))
    report = {"supported": False, "aliased_bytes": None,
              "donated_bytes": donated, "aliased_pairs": None}
    try:
        mem = compiled.memory_analysis()
        aliased = getattr(mem, "alias_size_in_bytes", None)
    except Exception:
        aliased = None
    if aliased is not None:
        report["supported"] = True
        report["aliased_bytes"] = int(aliased)
    try:
        hlo = compiled.as_text()
        import re
        m = re.search(r"input_output_alias=\{([^}]*(?:\}[^}]*)*?)\}\s*\n",
                      hlo)
        if m is None:
            m = re.search(r"input_output_alias=\{(.*?)\}\n", hlo, re.S)
        if m is not None:
            pairs = re.findall(r"\{[\d,\s]*\}:\s*\([^)]*\)", m.group(0))
            report["aliased_pairs"] = pairs
            report["supported"] = True
    except Exception:
        pass
    return report


class _DeadRoundAggregator:
    """One WARNING line per log interval instead of one per dead round.

    ``record(t)`` counts a dead round (no participants, server update
    skipped); ``flush()`` emits a single summary line — count plus the
    round span — if any were recorded since the last flush. The drivers
    flush at every ``log_every`` boundary and once at the end of the
    run, so a low ``sample_rate`` at small N (where a majority of
    rounds can be dead) cannot flood the log between loss lines.
    """

    def __init__(self, log):
        self._log = log
        self._count = 0
        self._first = self._last = 0

    def record(self, t: int) -> None:
        if self._count == 0:
            self._first = t
        self._last = t
        self._count += 1

    def flush(self) -> None:
        if not self._count:
            return
        span = (f"round {self._first + 1:5d}" if self._first == self._last
                else f"rounds {self._first + 1}-{self._last + 1}")
        self._log(f"{span}  WARNING: {self._count} dead round(s) — no "
                  "participants, server update skipped; consider a higher "
                  "sample_rate")
        self._count = 0


def run_rounds_slab(run_chunk, state: SlabTrainState, key, batch_fn,
                    n_rounds: int, chunk: int = 8,
                    adaptive_cfg: Optional[AdaptiveConfig] = None,
                    eval_fn: Optional[Callable] = None, eval_every: int = 0,
                    log_every: int = 0, log=print,
                    key_fn: Optional[Callable] = None, start_round: int = 0,
                    chunk_hook: Optional[Callable] = None,
                    align: Tuple[int, ...] = ()):
    """Slab-resident twin of ``run_rounds`` (host driver).

    ``run_chunk`` comes from ``make_slab_round_runner``. Rounds are
    dispatched in chunks of up to ``chunk`` (one scanned device program
    per chunk); by default the per-round PRNG keying is IDENTICAL to
    ``run_rounds`` — ``key, k_round, k_data = split(key, 3)`` per round,
    ``batch_fn(t, k_data)`` feeding host-side — so both drivers produce
    the same trajectory from the same key.

    ``key_fn(t) -> round key`` replaces the sequential split with
    keying by ABSOLUTE round index (``batch_fn`` then receives
    ``k_data=None``) — required when resuming from ``start_round > 0``,
    since round t's draws must not depend on how many rounds this
    process ran. Eval (which needs pytree params) happens only at chunk
    boundaries; chunks are clipped so every ``eval_every`` multiple —
    and every multiple of each period in ``align`` — IS a boundary.
    ``chunk_hook(t, state, history)`` runs after every chunk (e.g. for
    checkpointing). Returns ``(state, history)``.
    """
    if eval_fn is not None and adaptive_cfg is None:
        raise ValueError("eval_fn needs adaptive_cfg= to materialise params "
                         "at eval boundaries")
    if start_round and key_fn is None:
        raise ValueError("start_round > 0 needs key_fn= (absolute-index "
                         "keying); the sequential split would replay "
                         "round-0 draws")
    history = []
    dead = _DeadRoundAggregator(log)
    t = start_round
    while t < n_rounds:
        r = min(chunk, n_rounds - t)
        for period in (eval_every, *align):
            if period:
                r = min(r, period - t % period)
        ks, bs = [], []
        for i in range(r):
            if key_fn is not None:
                k_round, k_data = key_fn(t + i), None
            else:
                key, k_round, k_data = jax.random.split(key, 3)
            ks.append(k_round)
            bs.append(batch_fn(t + i, k_data))
        state, ms = run_chunk(state, jnp.stack(ks),
                              jax.tree.map(lambda *xs: jnp.stack(xs), *bs))
        loss = jax.device_get(ms.loss)
        gn = jax.device_get(ms.grad_norm)
        ngn = jax.device_get(ms.noisy_grad_norm)
        ah = jax.device_get(ms.alpha_hat)
        np_ = jax.device_get(ms.n_participants)
        for i in range(r):
            history.append({"round": t + i, "loss": float(loss[i]),
                            "grad_norm": float(gn[i]),
                            "noisy_grad_norm": float(ngn[i]),
                            "alpha_hat": float(ah[i]),
                            "n_participants": float(np_[i])})
            if float(np_[i]) == 0.0:
                dead.record(t + i)
        t += r
        if eval_fn is not None and eval_every and t % eval_every == 0:
            params, _ = unpack_train_state(adaptive_cfg, state)
            history[-1].update(eval_fn(params))
        if log_every:
            for i in range(t - r, t):
                if (i + 1) % log_every == 0:
                    dead.flush()
                    # history is indexed from start_round, i is absolute
                    _log_round(log, i, history[i - start_round])
        if chunk_hook is not None:
            chunk_hook(t, state, history)
    dead.flush()
    return state, history


def make_sharded_round_step(loss_fn: LossFn, channel_cfg: OTAChannelConfig,
                            adaptive_cfg: AdaptiveConfig,
                            client_axes: Tuple[str, ...] = ("data",)):
    """Distributed round step body — call inside ``shard_map``.

    Each shard group along ``client_axes`` is one client: it computes the
    gradient on its *local* batch, then the OTA collective aggregates.
    Model-parallel axes (if any) must be handled by the caller's model code;
    this body only owns the client/data axes.
    """
    server_opt = make_server_optimizer(adaptive_cfg)

    def body(params, opt_state: ServerOptState, key, local_batch):
        loss, local_grad = jax.value_and_grad(loss_fn)(params, local_batch)
        g_t = ota_psum(local_grad, key, channel_cfg, client_axes)
        new_params, new_state = server_opt.update(g_t, opt_state, params)
        loss = jax.lax.pmean(loss, client_axes)
        return new_params, new_state, loss

    return body


def run_rounds(round_step, params, opt_state, key, batch_fn, n_rounds: int,
               eval_fn: Optional[Callable] = None, eval_every: int = 0,
               log_every: int = 0, log=print):
    """Python-level training driver (data feeding is host-side).

    ``batch_fn(round_idx, key) -> client_batches``.
    Returns (params, opt_state, history list of dicts).
    """
    history = []
    dead = _DeadRoundAggregator(log)
    for t in range(n_rounds):
        key, k_round, k_data = jax.random.split(key, 3)
        batches = batch_fn(t, k_data)
        params, opt_state, m = round_step(params, opt_state, k_round, batches)
        rec = {"round": t, "loss": float(m.loss),
               "grad_norm": float(m.grad_norm),
               "noisy_grad_norm": float(m.noisy_grad_norm),
               "alpha_hat": float(m.alpha_hat),
               "n_participants": float(m.n_participants)}
        if rec["n_participants"] == 0.0:
            dead.record(t)
        if eval_fn is not None and eval_every and (t + 1) % eval_every == 0:
            rec.update(eval_fn(params))
        history.append(rec)
        if log_every and (t + 1) % log_every == 0:
            dead.flush()
            _log_round(log, t, rec)
    dead.flush()
    return params, opt_state, history
