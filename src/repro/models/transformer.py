"""Transformer block zoo + layer-stack machinery.

Every repeated stack is a ``jax.lax.scan`` over params stacked on a
leading layer axis (keeps HLO size O(1) in depth — essential for the
61/94-layer dry-runs), with optional ``jax.checkpoint`` (remat) around
the block body for training. Families:

  dense   — pre-norm GQA attention + (SwiGLU | GeLU) MLP
  mla     — pre-norm MLA attention + SwiGLU MLP (MiniCPM3)
  moe     — pre-norm GQA attention + top-k MoE FFN (+ shared expert)
  rwkv    — RWKV-6 time-mix + channel-mix
  hybrid  — Hymba: parallel {GQA attention, Mamba SSM} heads + SwiGLU MLP
  encdec  — Whisper: bidirectional encoder; decoder w/ self+cross attention
  vlm     — Llama-3.2-Vision: grouped scan, 1 gated cross-attn + 4 self
            layers per group
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (gelu_mlp, gelu_mlp_init,
                                 layernorm, layernorm_init, rmsnorm,
                                 rmsnorm_init, swiglu, swiglu_init)

PyTree = Any


def _norm_init(kind: str, dim: int) -> dict:
    return rmsnorm_init(dim) if kind == "rmsnorm" else layernorm_init(dim)


def _norm(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def _mlp_init(kind: str, key, d_model: int, d_ff: int, dtype) -> dict:
    return (swiglu_init(key, d_model, d_ff, dtype) if kind == "swiglu"
            else gelu_mlp_init(key, d_model, d_ff, dtype))


def _mlp(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return swiglu(p, x) if kind == "swiglu" else gelu_mlp(p, x)


def stack_init(block_init: Callable, key, n_layers: int) -> PyTree:
    """vmap a single-layer init over per-layer keys -> stacked params."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(block_init)(keys)


def stack_apply(block_fn: Callable, stacked: PyTree, x: jax.Array,
                aux0: Optional[jax.Array] = None, remat: bool = False,
                unroll: bool = False,
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """scan ``block_fn(layer_params, x) -> (x', aux)`` over the layer axis.
    aux (e.g. MoE load-balance loss) is accumulated additively.
    ``unroll`` materialises every layer in HLO — used by the dry-run's
    per-layer cost calibration (XLA cost analysis counts while bodies
    once, so scanned programs under-report; see launch/dryrun.py)."""
    fn = jax.checkpoint(block_fn) if remat else block_fn

    def body(carry, layer_params):
        x, aux = carry
        x, a = fn(layer_params, x)
        return (x, aux + a if aux is not None else None), None

    (x, aux), _ = jax.lax.scan(body, (x, aux0), stacked, unroll=unroll)
    return x, aux


def stack_decode(block_fn: Callable, stacked: PyTree, caches: PyTree,
                 x: jax.Array, unroll: bool = False
                 ) -> Tuple[jax.Array, PyTree]:
    """scan ``block_fn(layer_params, cache, x) -> (x', cache')`` over layers,
    threading per-layer caches (stacked on the layer axis)."""

    def body(x, layer):
        lp, cache = layer
        x, cache = block_fn(lp, cache, x)
        return x, cache

    x, new_caches = jax.lax.scan(body, x, (stacked, caches), unroll=unroll)
    return x, new_caches


def stack_prefill(block_fn: Callable, stacked: PyTree, x: jax.Array,
                  unroll: bool = False) -> Tuple[jax.Array, PyTree]:
    """scan ``block_fn(layer_params, x) -> (x', cache)`` collecting the
    per-layer caches (stacked on the layer axis) as scan outputs."""

    def body(x, lp):
        x, cache = block_fn(lp, x)
        return x, cache

    x, caches = jax.lax.scan(body, x, stacked, unroll=unroll)
    return x, caches


# --------------------------------------------------------------------------
# Block definitions. Each returns (init_fn(key) -> params,
#                                  fwd(params, x) -> (x, aux),
#                                  decode(params, cache, x, pos) -> (x, cache),
#                                  init_cache(batch, length) -> cache,
#                                  pfl(params, x, length) -> (x, cache))
# --------------------------------------------------------------------------

def dense_block(cfg) -> tuple:
    acfg = cfg.attn_config()
    norm, mlpk = cfg.norm, cfg.mlp

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": _norm_init(norm, cfg.d_model),
            "attn": attn.attn_init(k1, acfg, cfg.dtype),
            "ln2": _norm_init(norm, cfg.d_model),
            "mlp": _mlp_init(mlpk, k2, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    def fwd(p, x):
        s = x.shape[1]
        pos = jnp.arange(s, dtype=jnp.int32)
        x = x + attn.self_attention(p["attn"], acfg, _norm(norm, p["ln1"], x), pos)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, jnp.zeros((), jnp.float32)

    def decode(p, cache, x, pos):
        y, cache2 = attn.decode_self_attention(
            p["attn"], acfg, _norm(norm, p["ln1"], x), cache["kv"], pos)
        x = x + y
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {**cache, "kv": cache2}

    def init_cache(batch, length):
        return {"kv": attn.init_kv_cache(batch, length, acfg, cfg.dtype)}

    def pfl(p, x, length):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        y, kv = attn.prefill_kv_cache(p["attn"], acfg,
                                      _norm(norm, p["ln1"], x), pos, length)
        x = x + y
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {"kv": kv}

    return init, fwd, decode, init_cache, pfl


def mla_block(cfg) -> tuple:
    mcfg = cfg.mla_config()
    norm, mlpk = cfg.norm, cfg.mlp

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": _norm_init(norm, cfg.d_model),
            "attn": mla_mod.mla_init(k1, mcfg, cfg.dtype),
            "ln2": _norm_init(norm, cfg.d_model),
            "mlp": _mlp_init(mlpk, k2, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    def fwd(p, x):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = x + mla_mod.mla_self_attention(p["attn"], mcfg,
                                           _norm(norm, p["ln1"], x), pos)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, jnp.zeros((), jnp.float32)

    def decode(p, cache, x, pos):
        y, c2 = mla_mod.mla_decode_step(p["attn"], mcfg,
                                        _norm(norm, p["ln1"], x),
                                        cache["kv"], pos)
        x = x + y
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {**cache, "kv": c2}

    def init_cache(batch, length):
        return {"kv": mla_mod.init_mla_cache(batch, length, mcfg, cfg.dtype)}

    def pfl(p, x, length):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        y, kv = mla_mod.mla_prefill(p["attn"], mcfg,
                                    _norm(norm, p["ln1"], x), pos, length)
        x = x + y
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {"kv": kv}

    return init, fwd, decode, init_cache, pfl


def moe_block(cfg) -> tuple:
    acfg = cfg.attn_config()
    ecfg = cfg.moe_config()
    norm = cfg.norm

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": _norm_init(norm, cfg.d_model),
            "attn": attn.attn_init(k1, acfg, cfg.dtype),
            "ln2": _norm_init(norm, cfg.d_model),
            "moe": moe_mod.moe_init(k2, ecfg, cfg.dtype),
        }

    def fwd(p, x):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = x + attn.self_attention(p["attn"], acfg, _norm(norm, p["ln1"], x), pos)
        y, aux = moe_mod.moe_apply(p["moe"], ecfg, _norm(norm, p["ln2"], x))
        return x + y, aux

    def decode(p, cache, x, pos):
        y, c2 = attn.decode_self_attention(p["attn"], acfg,
                                           _norm(norm, p["ln1"], x),
                                           cache["kv"], pos)
        x = x + y
        y, _ = moe_mod.moe_apply(p["moe"], ecfg, _norm(norm, p["ln2"], x))
        return x + y, {**cache, "kv": c2}

    def init_cache(batch, length):
        return {"kv": attn.init_kv_cache(batch, length, acfg, cfg.dtype)}

    def pfl(p, x, length):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        y, kv = attn.prefill_kv_cache(p["attn"], acfg,
                                      _norm(norm, p["ln1"], x), pos, length)
        x = x + y
        y, _ = moe_mod.moe_apply(p["moe"], ecfg, _norm(norm, p["ln2"], x))
        return x + y, {"kv": kv}

    return init, fwd, decode, init_cache, pfl


def rwkv_block(cfg) -> tuple:
    rcfg = cfg.rwkv_config()

    def init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": layernorm_init(cfg.d_model),
            "tmix": rwkv_mod.time_mix_init(k1, rcfg, cfg.dtype),
            "ln2": layernorm_init(cfg.d_model),
            "cmix": rwkv_mod.channel_mix_init(k2, rcfg, cfg.dtype),
        }

    def fwd(p, x):
        x = x + rwkv_mod.time_mix_forward(p["tmix"], rcfg,
                                          layernorm(p["ln1"], x))
        x = x + rwkv_mod.channel_mix_forward(p["cmix"], rcfg,
                                             layernorm(p["ln2"], x))
        return x, jnp.zeros((), jnp.float32)

    def decode(p, cache, x, pos):
        del pos  # O(1) state, position-free
        y, c = rwkv_mod.time_mix_decode(p["tmix"], rcfg,
                                        layernorm(p["ln1"], x), cache["r"])
        x = x + y
        y, c = rwkv_mod.channel_mix_decode(p["cmix"], rcfg,
                                           layernorm(p["ln2"], x), c)
        return x + y, {**cache, "r": c}

    def init_cache(batch, length):
        del length  # O(1) state
        return {"r": rwkv_mod.init_rwkv_cache(batch, rcfg, cfg.dtype)}

    def pfl(p, x, length):
        del length
        h1 = layernorm(p["ln1"], x)
        y, s_fin, x_tm = rwkv_mod.time_mix_forward(p["tmix"], rcfg, h1,
                                                   return_state=True)
        x = x + y
        h2 = layernorm(p["ln2"], x)
        x = x + rwkv_mod.channel_mix_forward(p["cmix"], rcfg, h2)
        cache = {"r": {"state": s_fin, "x_tm": x_tm, "x_cm": h2[:, -1]}}
        return x, cache

    return init, fwd, decode, init_cache, pfl


def hybrid_block(cfg) -> tuple:
    """Hymba: attention and SSM branches in parallel on the same input,
    per-branch output norms, averaged; then a SwiGLU MLP."""
    acfg = cfg.attn_config()
    scfg = cfg.ssm_config()
    norm, mlpk = cfg.norm, cfg.mlp

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": _norm_init(norm, cfg.d_model),
            "attn": attn.attn_init(k1, acfg, cfg.dtype),
            "ssm": ssm_mod.ssm_init(k2, scfg, cfg.dtype),
            "attn_out_norm": rmsnorm_init(cfg.d_model),
            "ssm_out_norm": rmsnorm_init(cfg.d_model),
            "ln2": _norm_init(norm, cfg.d_model),
            "mlp": _mlp_init(mlpk, k3, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    def fwd(p, x):
        h = _norm(norm, p["ln1"], x)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        ya = rmsnorm(p["attn_out_norm"],
                     attn.self_attention(p["attn"], acfg, h, pos))
        ys = rmsnorm(p["ssm_out_norm"], ssm_mod.ssm_forward(p["ssm"], scfg, h))
        x = x + 0.5 * (ya + ys)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, jnp.zeros((), jnp.float32)

    def decode(p, cache, x, pos):
        h = _norm(norm, p["ln1"], x)
        ya, ckv = attn.decode_self_attention(p["attn"], acfg, h,
                                             cache["kv"], pos)
        ya = rmsnorm(p["attn_out_norm"], ya)
        ys, ch = ssm_mod.ssm_decode_step(p["ssm"], scfg, h, cache["ssm"])
        ys = rmsnorm(p["ssm_out_norm"], ys)
        x = x + 0.5 * (ya + ys)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {**cache, "kv": ckv, "ssm": ch}

    def init_cache(batch, length):
        return {"kv": attn.init_kv_cache(batch, length, acfg, cfg.dtype),
                "ssm": ssm_mod.init_ssm_cache(batch, scfg, cfg.dtype)}

    def pfl(p, x, length):
        h = _norm(norm, p["ln1"], x)
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        ya, kv = attn.prefill_kv_cache(p["attn"], acfg, h, pos, length)
        ya = rmsnorm(p["attn_out_norm"], ya)
        ys, sc = ssm_mod.ssm_forward(p["ssm"], scfg, h, return_state=True)
        ys = rmsnorm(p["ssm_out_norm"], ys)
        x = x + 0.5 * (ya + ys)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {"kv": kv, "ssm": sc}

    return init, fwd, decode, init_cache, pfl


def encdec_blocks(cfg) -> tuple:
    """Whisper-style: returns (enc_block fns, dec_block fns). Decoder blocks
    carry a cross-attention over the (stubbed) audio-frame embeddings."""
    # Whisper uses learned positions (added at the embedding), not RoPE.
    acfg = dataclasses.replace(cfg.attn_config(), rope=False)
    enc_acfg = dataclasses.replace(acfg, causal=False)
    x_acfg = dataclasses.replace(acfg, causal=False)
    norm, mlpk = cfg.norm, cfg.mlp

    def enc_init(key):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": _norm_init(norm, cfg.d_model),
            "attn": attn.attn_init(k1, enc_acfg, cfg.dtype),
            "ln2": _norm_init(norm, cfg.d_model),
            "mlp": _mlp_init(mlpk, k2, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    def enc_fwd(p, x):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = x + attn.self_attention(p["attn"], enc_acfg,
                                    _norm(norm, p["ln1"], x), pos)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, jnp.zeros((), jnp.float32)

    def dec_init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "ln1": _norm_init(norm, cfg.d_model),
            "self": attn.attn_init(k1, acfg, cfg.dtype),
            "lnx": _norm_init(norm, cfg.d_model),
            "cross": attn.attn_init(k2, x_acfg, cfg.dtype),
            "ln2": _norm_init(norm, cfg.d_model),
            "mlp": _mlp_init(mlpk, k3, cfg.d_model, cfg.d_ff, cfg.dtype),
        }

    def dec_fwd(p, x, enc_out):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = x + attn.self_attention(p["self"], acfg, _norm(norm, p["ln1"], x), pos)
        x = x + attn.cross_attention(p["cross"], x_acfg,
                                     _norm(norm, p["lnx"], x), enc_out)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, jnp.zeros((), jnp.float32)

    def dec_decode(p, cache, x, pos, enc_out):
        y, ckv = attn.decode_self_attention(p["self"], acfg,
                                            _norm(norm, p["ln1"], x),
                                            cache["kv"], pos)
        x = x + y
        x = x + attn.cross_attention(p["cross"], x_acfg,
                                     _norm(norm, p["lnx"], x), enc_out)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {**cache, "kv": ckv}

    def dec_init_cache(batch, length):
        return {"kv": attn.init_kv_cache(batch, length, acfg, cfg.dtype)}

    def dec_pfl(p, x, length, enc_out):
        pos = jnp.arange(x.shape[1], dtype=jnp.int32)
        y, kv = attn.prefill_kv_cache(p["self"], acfg,
                                      _norm(norm, p["ln1"], x), pos, length)
        x = x + y
        x = x + attn.cross_attention(p["cross"], x_acfg,
                                     _norm(norm, p["lnx"], x), enc_out)
        x = x + _mlp(mlpk, p["mlp"], _norm(norm, p["ln2"], x))
        return x, {"kv": kv}

    return ((enc_init, enc_fwd),
            (dec_init, dec_fwd, dec_decode, dec_init_cache, dec_pfl))


def vlm_group(cfg) -> tuple:
    """One Llama-3.2-Vision 'group': 1 gated cross-attn layer followed by
    (cross_attn_period - 1) self-attn layers. The stack scans groups."""
    acfg = cfg.attn_config()
    x_acfg = dataclasses.replace(acfg, causal=False, rope=False)
    norm, mlpk = cfg.norm, cfg.mlp
    n_self = cfg.cross_attn_period - 1
    d_init, d_fwd, d_decode, d_init_cache, d_pfl = dense_block(cfg)

    def init(key):
        kx, km, ks = jax.random.split(key, 3)
        return {
            "x_ln": _norm_init(norm, cfg.d_model),
            "x_attn": attn.attn_init(kx, x_acfg, cfg.dtype),
            "x_gate": jnp.zeros((), jnp.float32),
            "x_ln2": _norm_init(norm, cfg.d_model),
            "x_mlp": _mlp_init(mlpk, km, cfg.d_model, cfg.d_ff, cfg.dtype),
            "x_mlp_gate": jnp.zeros((), jnp.float32),
            "selfs": stack_init(d_init, ks, n_self),
        }

    def fwd(p, x, img):
        y = attn.cross_attention(p["x_attn"], x_acfg,
                                 _norm(norm, p["x_ln"], x), img)
        x = x + jnp.tanh(p["x_gate"]).astype(x.dtype) * y
        y = _mlp(mlpk, p["x_mlp"], _norm(norm, p["x_ln2"], x))
        x = x + jnp.tanh(p["x_mlp_gate"]).astype(x.dtype) * y
        x, _ = stack_apply(d_fwd, p["selfs"], x, jnp.zeros((), jnp.float32),
                           remat=cfg.remat, unroll=cfg.scan_unroll)
        return x, jnp.zeros((), jnp.float32)

    def decode(p, cache, x, pos, img):
        y = attn.cross_attention(p["x_attn"], x_acfg,
                                 _norm(norm, p["x_ln"], x), img)
        x = x + jnp.tanh(p["x_gate"]).astype(x.dtype) * y
        y = _mlp(mlpk, p["x_mlp"], _norm(norm, p["x_ln2"], x))
        x = x + jnp.tanh(p["x_mlp_gate"]).astype(x.dtype) * y
        x, c = stack_decode(lambda lp, ch, xx: d_decode(lp, ch, xx, pos),
                            p["selfs"], cache["selfs"], x,
                            unroll=cfg.scan_unroll)
        return x, {**cache, "selfs": c}

    def init_cache(batch, length):
        one = d_init_cache(batch, length)
        return {"selfs": jax.tree.map(
            lambda a: jnp.stack([a] * n_self), one)}

    def pfl(p, x, length, img):
        y = attn.cross_attention(p["x_attn"], x_acfg,
                                 _norm(norm, p["x_ln"], x), img)
        x = x + jnp.tanh(p["x_gate"]).astype(x.dtype) * y
        y = _mlp(mlpk, p["x_mlp"], _norm(norm, p["x_ln2"], x))
        x = x + jnp.tanh(p["x_mlp_gate"]).astype(x.dtype) * y
        x, caches = stack_prefill(lambda lp, xx: d_pfl(lp, xx, length),
                                  p["selfs"], x, unroll=cfg.scan_unroll)
        return x, {"selfs": caches}

    return init, fwd, decode, init_cache, pfl
