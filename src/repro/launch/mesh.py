"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — jax locks the device count at first
backend init, and only ``dryrun.py`` sets the 512-host-device XLA flag.
"""

from __future__ import annotations

import jax

from repro.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one 256-chip v5e pod) or 2x16x16 (two pods).

    Axes: "data" carries the FL clients (one client group per data
    shard), "model" carries tensor/expert parallelism, "pod" is the
    cross-pod data/FSDP axis in the multi-pod deployment.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The client-carrying axes of a mesh (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_clients_of(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in data_axes(mesh))
