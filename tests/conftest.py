# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512 devices.
import os
import sys

# Property tests use hypothesis, which the container may not ship. Fall
# back to the deterministic stub in _hypothesis_stub.py so the suite
# still collects and runs (conftest imports before any test module).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
