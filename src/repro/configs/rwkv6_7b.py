"""RWKV-6 Finch 7B [arXiv:2404.05892]: 32L, d_model 4096, attention-free
(64 heads of size 64 in the WKV state), d_ff 14336, vocab 65536;
data-dependent decay. O(1)-state decode -> long_500k native."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="rwkv6-7b", family="rwkv",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_ff=14336,
    vocab=65536, norm="layernorm", rwkv_lora_rank=64, rwkv_chunk=64,
    notes="Finch data-dependent decay [arXiv:2404.05892]",
)
