"""Attention paths: chunked online-softmax == full, window masks, MLA."""


import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.attention import (AttentionConfig, attend, attn_init,
                                    decode_self_attention,
                                    prefill_kv_cache, self_attention)


def _qkv(key, b, sq, sk, h, kh, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kh, d), jnp.float32)
    return q, k, v


@settings(max_examples=15, deadline=None)
@given(sk=st.integers(3, 70), chunk=st.sampled_from([4, 16, 32]),
       window=st.sampled_from([None, 8]))
def test_chunked_equals_full(sk, chunk, window):
    q, k, v = _qkv(jax.random.key(0), 2, sk, sk, 4, 2, 16)
    pos = jnp.arange(sk)
    full = attend(q, k, v, pos, pos, True, window, kv_chunk=None)
    chk = attend(q, k, v, pos, pos, True, window, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_causal_mask_blocks_future():
    """Changing a future token must not change past outputs."""
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = attn_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 10, 32), jnp.float32)
    pos = jnp.arange(10)
    y1 = self_attention(p, cfg, x, pos)
    x2 = x.at[:, -1].add(10.0)
    y2 = self_attention(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, :9]), np.asarray(y2[:, :9]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(y1[:, 9] - y2[:, 9]))) > 1e-3


def test_window_mask_limits_reach():
    """With window w, token t must not see tokens < t - w + 1."""
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
                          window=4)
    p = attn_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 12, 32), jnp.float32)
    pos = jnp.arange(12)
    y1 = self_attention(p, cfg, x, pos)
    x2 = x.at[:, 0].add(100.0)   # token 0 out of window for t >= 4
    y2 = self_attention(p, cfg, x2, pos)
    np.testing.assert_allclose(np.asarray(y1[:, 4:]), np.asarray(y2[:, 4:]),
                               rtol=1e-4, atol=1e-4)


def test_gqa_head_groups_use_right_kv():
    """With K=2 kv heads, q heads 0..1 use kv head 0; make kv head 1 huge
    and check only the second half of q heads changes."""
    b, s, h, kh, d = 1, 6, 4, 2, 8
    q, k, v = _qkv(jax.random.key(0), b, s, s, h, kh, d)
    pos = jnp.arange(s)
    base = attend(q, k, v, pos, pos, True, None)
    v2 = v.at[:, :, 1].add(5.0)
    mod = attend(q, k, v2, pos, pos, True, None)
    diff = np.abs(np.asarray(base - mod)).max(axis=(0, 1, 3))
    assert diff[0] < 1e-6 and diff[1] < 1e-6
    assert diff[2] > 1e-2 and diff[3] > 1e-2


def test_prefill_cache_then_decode_continuity():
    cfg = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8)
    p = attn_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 9, 32), jnp.float32)
    pos = jnp.arange(9)
    y_all = self_attention(p, cfg, x, pos)
    y_pre, cache = prefill_kv_cache(p, cfg, x[:, :8], jnp.arange(8), 16)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_all[:, :8]),
                               rtol=1e-5, atol=1e-5)
    y9, cache = decode_self_attention(p, cfg, x[:, 8:9], cache,
                                      jnp.asarray(8))
    np.testing.assert_allclose(np.asarray(y9), np.asarray(y_all[:, 8:9]),
                               rtol=1e-5, atol=1e-5)


def test_mla_cache_is_compressed():
    """The whole point of MLA: cache stores kv_lora + rope dims per token,
    NOT n_heads * head_dim * 2."""
    from repro.models.mla import MLAConfig, init_mla_cache
    cfg = MLAConfig(d_model=64, n_heads=8, q_lora_rank=32, kv_lora_rank=16,
                    qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8)
    cache = init_mla_cache(2, 100, cfg)
    per_token = (cache["c_kv"].shape[-1] + cache["k_pe"].shape[-1])
    full_kv = 2 * cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
    assert per_token == 20
    assert per_token * 12 < full_kv  # >12x compression at this config
