"""Benchmark harness: one entry per paper table/figure + kernel
microbenches + the roofline summary. Prints ``name,us_per_call,derived``
CSV (one line per benchmark record).

    PYTHONPATH=src python -m benchmarks.run              # full
    PYTHONPATH=src python -m benchmarks.run --quick      # reduced rounds
    PYTHONPATH=src python -m benchmarks.run --only fig5
"""

from __future__ import annotations

import argparse
import datetime
import hashlib
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import kernel_bench, paper_figs  # noqa: E402


REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# Every bench the harness knows; --only must name one of these.
BENCH_NAMES = ("fig2", "fig3", "fig4", "fig5", "fig6", "fig7",
               "beyond_yogi", "kernels", "round_step", "train_loop")


def _git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True, cwd=REPO_ROOT,
                             timeout=10)
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def bench_meta(quick: bool, config: dict) -> dict:
    """Provenance stamp for the tracked BENCH_*.json artifacts: git SHA
    + UTC date make a record attributable to a PR, and the fingerprint
    (a hash of the bench configuration + the software/platform that
    produced it) makes cross-PR comparisons refuse-on-drift — two runs
    are comparable iff their fingerprints match."""
    import jax
    from repro.kernels.interpret import INTERPRET_ENV, resolve_interpret
    cfg = dict(config, quick=quick, jax=jax.__version__,
               jax_backend=jax.default_backend(),
               interpret=resolve_interpret(None),
               interpret_env=os.environ.get(INTERPRET_ENV),
               python=".".join(map(str, sys.version_info[:3])))
    fp = hashlib.sha256(
        json.dumps(cfg, sort_keys=True).encode()).hexdigest()[:16]
    return {"git_sha": _git_sha(),
            "date": datetime.datetime.now(datetime.timezone.utc)
                        .strftime("%Y-%m-%dT%H:%M:%SZ"),
            "config": cfg, "config_fingerprint": fp}


def _csv(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def _bench_subprocess(module: str, argv: list) -> list:
    """Run a bench module in a subprocess and parse its JSON records.

    The multi-device benches (shard_bench, train_loop_bench) need forced
    host devices, and this process must keep jax's real single-device
    view (jax locks the device count at first backend init) — so they
    force the override in their own interpreter and ship records back
    as JSON on stdout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO_ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", module, *argv],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"{module} failed: {out.stderr[-500:]}")
    return json.loads(out.stdout)


def _load_prev_bench(filename: str) -> dict:
    """The tracked repo-root artifact this run is about to replace (the
    PREVIOUS PR's records), or {} when absent/unreadable."""
    path = os.path.join(REPO_ROOT, filename)
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            prev = json.load(f)
        return prev if isinstance(prev, dict) else {}
    except (OSError, json.JSONDecodeError):
        return {}


def _delta_column(rec: dict, prev: dict, comparable: bool) -> str:
    """Per-record delta vs the previous artifact's same-name record:
    throughput change in percent (positive == faster) on the record's
    headline rate (clients/sec for streamed records, rounds/sec
    otherwise). Non-comparable runs (config fingerprint drift) refuse a
    number rather than reporting a meaningless one."""
    by_name = {r.get("name"): r for r in prev.get("records", [])}
    old = by_name.get(rec["name"])
    if old is None:
        return "delta=new"
    if not comparable:
        return "delta=incomparable(fingerprint-drift)"
    key = ("clients_per_sec" if "clients_per_sec" in rec
           else "rounds_per_sec")
    if key not in old or not old[key]:
        return "delta=new-metric"
    pct = (rec[key] / old[key] - 1.0) * 100.0
    sha = str(prev.get("meta", {}).get("git_sha", "unknown"))[:7]
    return f"delta_{key}={pct:+.1f}%_vs_{sha}"


def _write_bench_json(filename: str, records: list, quick: bool,
                      out_dir: str, config: dict,
                      compare: bool = False) -> None:
    """Tracked artifacts live at the repo root; a --quick run is
    reduced-fidelity, so it writes under ``out_dir`` instead of
    clobbering them. The payload is ``{"meta": ..., "records": [...]}``
    — see ``bench_meta`` for the provenance contract.

    ``compare`` appends a per-record delta column against the previous
    repo-root artifact (matched by record name, attributed to its
    ``meta.git_sha``) and, full runs only, carries the lineage forward:
    ``meta.trajectory`` lists the provenance (sha, date, fingerprint)
    of every prior run of this artifact, newest last, so a tracked
    BENCH_*.json records its own perf history across PRs."""
    meta = bench_meta(quick, config)
    prev = _load_prev_bench(filename)
    prev_meta = prev.get("meta", {}) if isinstance(
        prev.get("meta"), dict) else {}
    comparable = (prev_meta.get("config_fingerprint")
                  == meta["config_fingerprint"])
    for r in records:
        derived = r["derived"]
        if compare and prev:
            derived = f"{derived};{_delta_column(r, prev, comparable)}"
        _csv(r["name"], r["us_per_round"], derived)
    if not quick:
        # Lineage rides the artifact itself (bounded — the artifact
        # must not grow without limit in git).
        trajectory = list(prev_meta.get("trajectory", []))
        if prev_meta.get("git_sha"):
            trajectory.append({
                k: prev_meta.get(k)
                for k in ("git_sha", "date", "config_fingerprint")})
        meta["trajectory"] = trajectory[-20:]
    dest = out_dir if quick else REPO_ROOT
    with open(os.path.join(dest, filename), "w") as f:
        json.dump({"meta": meta, "records": records}, f, indent=2)


def run_round_step_bench(quick: bool, out_dir: str,
                         compare: bool = False) -> list:
    """Full-round benchmark, jnp vs pallas-slab vs mesh-sharded slab, on
    >= 2 model sizes; the records land in BENCH_round_step.json at the
    repo root so the perf trajectory is tracked across PRs."""
    sizes = (1 << 14, 1 << 16) if quick else (1 << 14, 1 << 16, 1 << 18)
    iters = 2 if quick else 5
    records = []
    for n_params in sizes:
        records.extend(kernel_bench.bench_round_step(n_params, iters=iters))
    # No stub record on failure: a full run would clobber the tracked
    # repo-root artifact with it, and a quick run would exit 0 under CI;
    # main() turns the raise into a round_step:ERROR line + exit 1.
    records.extend(_bench_subprocess(
        "benchmarks.shard_bench",
        ["--sizes", *[str(s) for s in sizes], "--iters", str(iters)]))
    _write_bench_json("BENCH_round_step.json", records, quick, out_dir,
                      {"bench": "round_step", "sizes": list(sizes),
                       "iters": iters}, compare=compare)
    return records


def run_train_loop_bench(quick: bool, out_dir: str,
                         compare: bool = False) -> list:
    """Multi-round loop benchmark: the slab-RESIDENT engine (scan over a
    SlabTrainState) vs the per-round pytree API, single-device and on a
    (2,)-mesh, with rounds/sec and per-round bytes-moved estimates. The
    records land in BENCH_train_loop.json at the repo root (the sibling
    of BENCH_round_step.json)."""
    sizes = (1 << 14,) if quick else (1 << 14, 1 << 16)
    rounds = 4 if quick else 8
    iters = 1 if quick else 2
    # Streamed-client-axis records (clients/sec): quick stops at 1e5;
    # the full tier includes the million-client round — the O(chunk)
    # memory headline a resident stack cannot reach on one host.
    stream_clients = ([1_000, 100_000] if quick
                      else [1_000, 100_000, 1_000_000])
    records = _bench_subprocess(
        "benchmarks.train_loop_bench",
        ["--sizes", *[str(s) for s in sizes], "--rounds", str(rounds),
         "--iters", str(iters),
         "--stream-clients", *[str(n) for n in stream_clients]])
    _write_bench_json("BENCH_train_loop.json", records, quick, out_dir,
                      {"bench": "train_loop", "sizes": list(sizes),
                       "rounds": rounds, "iters": iters,
                       "stream_clients": stream_clients}, compare=compare)
    return records


def run_paper_fig(fig_name: str, quick: bool) -> list:
    if quick:
        paper_figs.ROUNDS = 30
    fn = getattr(paper_figs, fig_name)
    records = fn()
    for r in records:
        tag = (f"{fig_name}:{r['optimizer']}"
               + (f":a{r['alpha']}" if fig_name == "fig5" else "")
               + (f":b2_{r['beta2']}" if fig_name == "fig4" else "")
               + (f":N{r['n_clients']}" if fig_name == "fig6" else "")
               + (f":dir{r['dir_alpha']}" if fig_name == "fig7" else ""))
        _csv(tag, r["us_per_round"],
             f"final_loss={r['final_loss']:.4f};acc={r['accuracy']:.4f}")
    return records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--compare", action="store_true",
                    help="append a per-record delta column vs the previous "
                         "tracked BENCH_*.json (matched by record name, "
                         "attributed to its meta.git_sha; refuses a number "
                         "when the config fingerprints drifted)")
    args = ap.parse_args()
    if args.only and args.only not in BENCH_NAMES:
        ap.error(f"unknown bench name {args.only!r} for --only; "
                 f"valid names: {', '.join(BENCH_NAMES)}")
    os.makedirs(args.out, exist_ok=True)

    print("name,us_per_call,derived")
    figs = ["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "beyond_yogi"]
    if args.only:
        figs = [f for f in figs if f == args.only]
    all_records = {}
    for fig in figs:
        try:
            all_records[fig] = run_paper_fig(fig, args.quick)
        except Exception as e:  # noqa: BLE001
            _csv(f"{fig}:ERROR", 0.0, repr(e)[:80])

    if not args.only or args.only == "kernels":
        for rec in kernel_bench.all_benches():
            _csv(rec["name"], rec["us_per_call"], rec["derived"])

    failed = False
    if not args.only or args.only == "round_step":
        try:
            all_records["round_step"] = run_round_step_bench(
                args.quick, args.out, compare=args.compare)
        except Exception as e:  # noqa: BLE001
            _csv("round_step:ERROR", 0.0, repr(e)[:80])
            failed = True

    if not args.only or args.only == "train_loop":
        try:
            all_records["train_loop"] = run_train_loop_bench(
                args.quick, args.out, compare=args.compare)
        except Exception as e:  # noqa: BLE001
            _csv("train_loop:ERROR", 0.0, repr(e)[:80])
            failed = True

    # Roofline summary (if dry-run artifacts exist).
    try:
        from benchmarks import roofline
        recs = roofline.load_records()
        n_ok = sum(1 for r in recs if r.get("ok"))
        _csv("dryrun:combos_ok", 0.0, f"ok={n_ok};total={len(recs)}")
        for r in recs:
            t = roofline.terms(r)
            if t and t["mesh"] == "single":
                _csv(f"roofline:{t['arch']}:{t['shape']}",
                     max(t['compute_s'], t['memory_s'],
                         t['collective_s']) * 1e6,
                     f"dominant={t['dominant']};useful={t['useful_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001
        _csv("roofline:ERROR", 0.0, repr(e)[:80])

    # Slab-engine roofline grades from the tracked BENCH artifacts just
    # (re)written above: v5e byte-model floors always; wall-clock
    # attainment only for compiled-mode records (see benchmarks/
    # roofline.py — interpret provenance gates the grading).
    try:
        from benchmarks import roofline
        for g in roofline.grade_bench():
            att = (f"{g['attainment']:.3f}" if g["attainment"] is not None
                   else "interpret")
            _csv(f"roofline_slab:{g['name']}", g["floor_s"] * 1e6,
                 f"bound={g['bound']};attainment={att}")
    except Exception as e:  # noqa: BLE001
        _csv("roofline_slab:ERROR", 0.0, repr(e)[:80])

    with open(os.path.join(args.out, "paper_figs.json"), "w") as f:
        json.dump(all_records, f, indent=2)
    if failed:
        # The tracked round_step artifact is the perf trajectory; exiting
        # 0 on a failed run would let it rot silently under CI.
        sys.exit(1)


if __name__ == "__main__":
    main()
