"""Qwen3-14B [hf:Qwen/Qwen3-14B family]: 40L, d_model 5120, 40 heads
(GQA kv=8, head_dim 128), d_ff 17408, vocab 151936; per-head qk-norm,
no biases, RMSNorm + SwiGLU."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1000000.0,
    notes="qk_norm, GQA [hf:Qwen/Qwen3-8B card family]",
)
