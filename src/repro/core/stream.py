"""Streamed client axis: O(chunk * d) rounds, participation, weights.

The resident round materialises all N client gradients as one (N, d)
slab before the MAC — N is capped by host memory, not by the channel.
This module makes N a STREAMED axis instead (ROADMAP Open item 1): the
round scans the client population in chunks of ``FLConfig.client_chunk``
rows, each chunk's gradients are computed, faded, and folded into the
running (d,) partial sum by the accumulating transmit kernel
(``ota_transmit_slab(..., acc=...)``), and only the completed partial
crosses the channel. Peak memory is O(chunk * d) regardless of N — a
million simulated clients fit on one CPU host.

Two wireless extensions ride on the same streamed transmit stage, both
folded into the EFFECTIVE fading coefficient next to power control:

* **Partial participation** — per-round Bernoulli sampling of the
  client population (``FLConfig.sample_rate``). The mask is one full
  (N,) uniform draw keyed off the round key via the ``PART_FOLD``
  domain separator, never re-keyed per chunk or per shard — the same
  full-draws-sliced contract as fading and stochastic rounding, so all
  three backends (and every mesh shape) sample literally identical
  clients.
* **Per-client aggregation weights** — ``FLConfig.client_weights``
  (e.g. dataset sizes, arXiv 2409.07822's weighted aggregation).

With sampling/weights active the 1/N normaliser becomes
``1 / sum_n mask_n * w_n``: the transmit launches accumulate the raw
weighted faded sum (``n_total=1``) and the divisor is applied once to
the completed partial, guarded against the zero-participation round
(``norm_safe``; the round-step layer then SKIPS the server update so
the state is unchanged — see ``make_slab_round_step``). Without them
(``dynamic_norm`` False) the static ``1/n_clients`` divisor stays
in-kernel, bit for bit.

**Bitwise contract.** The finish stage pushes the completed partial
through a single-ROW launch of the same fused channel/quantize kernels
the resident path uses (``sum(1 * x)/1 == x`` exactly in f32), so with
``chunk >= N``, full participation and no weights, the streamed round
executes the exact resident op sequence and is bitwise-identical to
the resident slab round on ``uplink="f32"`` — streaming is a pure
memory optimization (tests/test_stream.py pins this un-jitted; under
``jax.jit`` XLA may reassociate the client reduction differently
between the two programs, so jitted trajectories are pinned at 1e-5
like every other cross-engine pair). Uniform weights ``(c, ..., c)``
likewise reduce to the 1/N path: the accumulated sum is
``sum(h * c * g)`` and the divisor ``N * c``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.channel import (OTAChannelConfig, sample_fading,
                                sr_kernel_seed)
from repro.core.ota import (_interference_slab_inputs, restore_zero_tail,
                            uplink_sr_slab_inputs)
from repro.core.slab import SlabSpec, stack_to_slab
from repro.kernels.interpret import resolve_interpret
from repro.kernels.ota_channel import (ota_channel_slab, ota_receive_slab,
                                       ota_transmit_slab, pack_sign_slab)
from repro.kernels.ref import (ota_channel_ref, ota_receive_ref,
                               ota_transmit_ref)

PyTree = Any

# PRNG domain separator of the participation draw (the same role
# channel.SR_FOLD plays for stochastic rounding): the (N,) mask uniforms
# are always ONE full draw from fold_in(round_key, PART_FOLD), sliced by
# whoever needs a sub-range — never re-keyed — so jnp / pallas /
# pallas_sharded sample identical clients by construction.
PART_FOLD = 0xACCE


def participation_mask(key: jax.Array, n_clients: int,
                       sample_rate: float) -> jax.Array:
    """This round's (N,) participation mask as f32 {0, 1}.

    ``sample_rate >= 1`` short-circuits to all-ones WITHOUT consuming
    PRNG state, so enabling sampling never perturbs the fading /
    interference / SR draws of existing configs (and rate == 1 rounds
    stay bitwise-identical to pre-sampling code)."""
    if sample_rate >= 1.0:
        return jnp.ones((n_clients,), jnp.float32)
    u = jax.random.uniform(jax.random.fold_in(key, PART_FOLD),
                           (n_clients,), jnp.float32)
    return (u < sample_rate).astype(jnp.float32)


def client_weight_array(fl_cfg) -> Optional[jax.Array]:
    """The (N,) f32 aggregation-weight vector, or None when uniform."""
    if fl_cfg.client_weights is None:
        return None
    return jnp.asarray(fl_cfg.client_weights, jnp.float32)


def round_participation(key: jax.Array, fl_cfg):
    """(mask, gain) of this round: the {0,1} participation mask and the
    per-client transmit gain (mask * weights) that multiplies the
    fading draw. Both full (N,) — sharded callers slice their rows."""
    mask = participation_mask(key, fl_cfg.n_clients, fl_cfg.sample_rate)
    w = client_weight_array(fl_cfg)
    gain = mask if w is None else mask * w
    return mask, gain


class StreamParts(NamedTuple):
    """Everything one streamed uplink pass produces (single device)."""
    g_slab: jax.Array         # (padded,) noisy aggregate after the channel
    h: jax.Array              # (N,) raw fading draw (for metrics)
    mask: jax.Array           # (N,) participation mask
    n_participants: jax.Array  # scalar f32: sum(mask)
    norm: jax.Array           # scalar f32 normaliser: sum(mask * w)
    loss_sum: jax.Array       # sum of participating clients' losses
    clean_slab: jax.Array     # (padded,) unfaded participant gradient sum
    stats: Optional[jax.Array]  # (3,) pilot log-moments (pilot_stats=True)
    ef_new: Optional[jax.Array] = None  # (padded,) fresh EF residual
                                        # (error feedback on a quantized
                                        # uplink; None otherwise)


def streamed_round_parts(key: jax.Array, channel_cfg: OTAChannelConfig,
                         fl_cfg, spec: SlabSpec,
                         client_fn: Callable, params: PyTree,
                         client_batches: PyTree = None,
                         batch_gen: Optional[Callable] = None,
                         pilot_stats: bool = False,
                         use_kernels: bool = True,
                         ef: Optional[jax.Array] = None) -> StreamParts:
    """One streamed uplink pass: scan the client axis in chunks, fold
    each chunk into the running partial via the accumulating transmit
    kernel, then push the completed partial through the single-row
    channel (or quantize + receive) launch.

    ``client_batches`` holds materialised per-client batches (leaves
    (N, ...), sliced per chunk); ``batch_gen(key, idx)`` instead
    synthesizes the batch of the ``idx`` (chunk,)-int32 client rows
    in-graph — required for client populations too large to materialise
    (the million-client benchmark). Exactly one of the two.

    A ``client_chunk`` that does NOT divide ``n_clients`` is served by a
    RAGGED final chunk: the tail rows past N are padding — their
    effective fading is zero (so they fold exactly 0.0 into the
    partial; their batch rows re-read row N-1, whose gradient is then
    multiplied by that zero) and their mask is zero (so clean/loss sums
    ignore them). All per-client draws stay full (N,) draws, so ragged
    chunking consumes identical PRNG state to any other chunking of the
    same round.

    ``use_kernels=False`` runs the op-mirrored ``kernels.ref`` path over
    the same slab layout and the same draws (the jnp backend).
    ``ef`` is this transmitter's carried (padded,) error-feedback
    residual: it joins the completed partial before the finish-stage
    quantizer (quantized uplink only) and the fresh residual comes back
    as ``StreamParts.ef_new``.
    """
    cfg = channel_cfg
    n = fl_cfg.n_clients
    chunk = min(fl_cfg.client_chunk or n, n)
    if (client_batches is None) == (batch_gen is None):
        raise ValueError("pass exactly one of client_batches / batch_gen")
    if ef is not None and not cfg.uplink.quantized:
        raise ValueError("ef= (error feedback) needs a quantized uplink; "
                         'the "f32" payload has no residual')

    mask, gain = round_participation(key, fl_cfg)
    dynamic_norm = fl_cfg.dynamic_norm
    kh, kx = jax.random.split(key)
    h = sample_fading(kh, cfg, (n,))
    # Participation and weights fold into the EFFECTIVE fading, next to
    # power control; with neither active h_eff is h * 1.0 == h bitwise
    # and the static 1/N divisor stays in-kernel.
    h_eff = h * gain if dynamic_norm else h
    n_div = 1 if dynamic_norm else n
    # Ragged final chunk: pad the PER-ROW operands (effective fading,
    # mask) with zero rows up to the next chunk multiple. The padded
    # rows transmit with zero gain and count for nothing; the draws
    # above were taken at full (N,) BEFORE padding, so the PRNG stream
    # is untouched. When chunk | N this is a no-op (zero-length pad),
    # keeping the divisible path bitwise-identical.
    n_chunks = -(-n // chunk)
    n_padded = n_chunks * chunk
    if n_padded != n:
        h_sched = jnp.pad(h_eff, (0, n_padded - n))
        mask_sched = jnp.pad(mask, (0, n_padded - n))
    else:
        h_sched, mask_sched = h_eff, mask

    if use_kernels:
        def transmit(g_stack, h_c, acc):
            return ota_transmit_slab(g_stack, h_c, n_total=n_div, acc=acc,
                                     interpret=cfg.interpret)
    else:
        def transmit(g_stack, h_c, acc):
            return ota_transmit_ref(g_stack, h_c, n_total=n_div, acc=acc)

    ragged = n_padded != n

    def produce(c):
        """Chunk c's client compute + per-chunk operand slices: the
        SLOT of the double-buffered pipeline (everything chunk c
        contributes, before any accumulator is touched)."""
        start = c * chunk
        idx = start + jnp.arange(chunk)
        if ragged:
            # Padding rows re-read row N-1; its gradient lands with the
            # zero gain/mask of the padded schedule rows, so it folds
            # exactly 0.0 into every accumulator.
            idx = jnp.minimum(idx, n - 1)
        if batch_gen is not None:
            batch = batch_gen(key, idx)
        elif ragged:
            batch = jax.tree.map(lambda b: jnp.take(b, idx, axis=0),
                                 client_batches)
        else:
            batch = jax.tree.map(
                lambda b: jax.lax.dynamic_slice_in_dim(b, start, chunk),
                client_batches)
        grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(params, batch)
        g_stack = stack_to_slab(spec, grads)
        h_c = jax.lax.dynamic_slice_in_dim(h_sched, start, chunk)
        m_c = jax.lax.dynamic_slice_in_dim(mask_sched, start, chunk)
        return g_stack, h_c, m_c, losses

    def body(carry, c):
        acc, clean, loss_sum = carry
        g_stack, h_c, m_c, losses = produce(c)
        acc = transmit(g_stack, h_c, acc)
        clean = clean + jnp.sum(m_c[:, None] * g_stack, axis=0)
        loss_sum = loss_sum + jnp.sum(m_c * losses)
        return (acc, clean, loss_sum), None

    def fold(carry, slot):
        """Double-buffered fold: one fused pass folds a completed slot
        into the accumulators. The faded and clean partials reduce
        TOGETHER as a (2, chunk) @ (chunk, d) product — one read of the
        gradient stack instead of two elementwise-multiply+reduce
        passes — which reassociates the per-chunk sum (the documented
        tolerance-tier trade of ``FLConfig.double_buffer``)."""
        acc, clean, loss_sum = carry
        g_stack, h_c, m_c, losses = slot
        coeff = jnp.stack([h_c * (1.0 / n_div), m_c])
        both = coeff @ g_stack
        return (acc + both[0], clean + both[1],
                loss_sum + jnp.sum(m_c * losses))

    def db_body(carry, c):
        """Two-slot pipeline step: issue chunk c's client compute, then
        fold chunk c-1's prefetched slot. The two stages share no data
        dependency, so the runtime is free to run chunk c's gradients
        while chunk c-1's accumulation is in flight."""
        acc, clean, loss_sum, slot = carry
        new_slot = produce(c)
        acc, clean, loss_sum = fold((acc, clean, loss_sum), slot)
        return (acc, clean, loss_sum, new_slot), None

    zeros = jnp.zeros((spec.padded,), jnp.float32)
    if n == chunk:
        # Single chunk — the chunk >= N parity configuration: no scan,
        # no dynamic slicing (a traced slice start changes how XLA
        # fuses the client-gradient graph, costing the bitwise
        # contract), just the resident compute feeding the
        # accumulating kernel once.
        batch = (batch_gen(key, jnp.arange(n)) if batch_gen is not None
                 else client_batches)
        grads, losses = jax.vmap(client_fn, in_axes=(None, 0))(params, batch)
        g_stack = stack_to_slab(spec, grads)
        acc = transmit(g_stack, h_eff, zeros)
        clean = jnp.sum(mask[:, None] * g_stack, axis=0)
        loss_sum = jnp.sum(mask * losses)
    elif fl_cfg.double_buffer:
        # Prologue: chunk 0 fills the slot before the pipeline starts;
        # steady state overlaps produce(c) with fold(c-1); the epilogue
        # drains the final slot. Same draws, same chunk schedule, same
        # batch selection as the serial scan — only the accumulation
        # order moves.
        carry = (zeros, zeros, jnp.zeros((), jnp.float32), produce(0))
        carry, _ = jax.lax.scan(db_body, carry,
                                jnp.arange(1, n_chunks, dtype=jnp.int32))
        acc, clean, loss_sum = fold(carry[:3], carry[3])
    else:
        carry = (zeros, zeros, jnp.zeros((), jnp.float32))
        carry, _ = jax.lax.scan(body, carry,
                                jnp.arange(n_chunks, dtype=jnp.int32))
        acc, clean, loss_sum = carry

    n_part = jnp.sum(mask)
    norm = jnp.sum(gain) if dynamic_norm else n_part
    if dynamic_norm:
        # Zero-participation guard: a dead round divides by 1 (the
        # partial is all-zero anyway) and the round step SKIPS the
        # server update; max(norm, 1) would instead corrupt legitimate
        # fractional-weight rounds.
        norm_safe = jnp.where(norm > 0.0, norm, 1.0)
        g_pre = acc / norm_safe
    else:
        g_pre = acc

    # Finish: the completed partial crosses the channel through the SAME
    # fused kernels as the resident round, as a single transmitter row —
    # sum(1 * x)/1 == x exactly, so op order (and hence bitwise parity
    # with the resident launch) is preserved.
    u, e, scale = _interference_slab_inputs(kx, cfg, spec)
    one = jnp.ones((1,), jnp.float32)
    stats = None
    ef_new = None
    if cfg.uplink.quantized:
        qmode = cfg.uplink.mode
        zero_fold = cfg.uplink.zero_fold
        packed = cfg.uplink.packed_sign
        stochastic = cfg.uplink.stochastic_rounding and qmode == "int8"
        inkernel = (stochastic and cfg.uplink.sr_inkernel and use_kernels
                    and not resolve_interpret(cfg.interpret))
        r = (uplink_sr_slab_inputs(key, spec)[0]
             if stochastic and not inkernel else None)
        want_ef = ef is not None
        if use_kernels:
            sr_seed = sr_kernel_seed(key)[0] if inkernel else None
            tx = ota_transmit_slab(g_pre[None], one, n_total=1,
                                   quantize=True, r=r,
                                   stochastic=stochastic, qmode=qmode,
                                   zero_fold=zero_fold, sr_seed=sr_seed,
                                   ef=ef, return_residual=want_ef,
                                   interpret=cfg.interpret)
            payload = (pack_sign_slab(tx[0][None],
                                      planes=(packed == "planes"))
                       if packed else tx[0][None])
            g_slab = ota_receive_slab(payload, tx[1][None], u, e,
                                      alpha=cfg.alpha, scale=scale,
                                      packed=packed,
                                      pilot_stats=pilot_stats,
                                      interpret=cfg.interpret)
        else:
            tx = ota_transmit_ref(g_pre[None], one, n_total=1,
                                  quantize=True, r=r,
                                  stochastic=stochastic, qmode=qmode,
                                  zero_fold=zero_fold,
                                  ef=ef, return_residual=want_ef)
            payload = (pack_sign_slab(tx[0][None],
                                      planes=(packed == "planes"))
                       if packed else tx[0][None])
            g_slab = ota_receive_ref(payload, tx[1][None], u, e,
                                     alpha=cfg.alpha, scale=scale,
                                     packed=packed,
                                     pilot_stats=pilot_stats)
        if want_ef:
            ef_new = tx[2]
    else:
        if use_kernels:
            g_slab = ota_channel_slab(g_pre[None], one, u, e,
                                      alpha=cfg.alpha, scale=scale,
                                      n_total=1, pilot_stats=pilot_stats,
                                      interpret=cfg.interpret)
        else:
            g_slab = ota_channel_ref(g_pre[None], one, u, e,
                                     alpha=cfg.alpha, scale=scale,
                                     pilot_stats=pilot_stats)
    if pilot_stats:
        g_slab, stats = g_slab
    if cfg.uplink.quantized and cfg.uplink.zero_fold:
        g_slab = restore_zero_tail(g_slab, spec)
        ef_new = restore_zero_tail(ef_new, spec)

    return StreamParts(g_slab=g_slab, h=h, mask=mask,
                       n_participants=n_part, norm=norm,
                       loss_sum=loss_sum, clean_slab=clean, stats=stats,
                       ef_new=ef_new)
