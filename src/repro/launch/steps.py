"""Step builders: the four lowered programs of the dry-run matrix, with
their in/out shardings, assembled for ``jax.jit`` under a production mesh.

The training step IS the paper's Algorithm 1 mapped onto the mesh:
every (pod, data) shard group is one FL client; per-client Rayleigh
fading enters as per-example loss weights (exactly equivalent to scaling
each client's gradient — fading is linear); the gradient all-reduce that
GSPMD inserts across the data axes realises the over-the-air
superposition; the shared-seed alpha-stable interference is added to the
aggregated gradient; then the ADOTA adaptive update runs on the (model-
sharded) server state.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import jax.tree_util as jtu

from repro.core.adaptive import (AdaptiveConfig, ServerOptState,
                                 make_server_optimizer)
from repro.core.channel import OTAChannelConfig
from repro.core.ota import add_interference, faded_loss_weights
from repro.launch import specs as S
from repro.launch.mesh import data_axes, n_clients_of
from repro.models.model import ModelConfig, build_model, partition_spec
from repro.models.moe import set_moe_sharding

PyTree = Any


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Distribution & optimizer knobs for a launch."""
    channel: OTAChannelConfig = OTAChannelConfig()
    adaptive: AdaptiveConfig = AdaptiveConfig(optimizer="adam_ota")
    fsdp: bool = False               # additionally shard params over data
    shard_cache_seq: bool = False    # split-KV decode (perf lever)
    state_dtype: str = "float32"     # ADOTA Delta/nu dtype (bf16 = mem lever)


class LoweredPieces(NamedTuple):
    step_fn: Any
    args: tuple            # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def _param_shardings(cfg: ModelConfig, mesh, model, fsdp: bool,
                     decode: bool = False):
    pshape = jax.eval_shape(model.init, jax.random.key(0))
    msize = mesh.shape["model"]
    daxes = data_axes(mesh)
    fsdp_axis = daxes if fsdp else None
    fsdp_size = math.prod(mesh.shape[a] for a in daxes) if fsdp else 1
    pspec = partition_spec(cfg, pshape, "model", msize,
                           fsdp_axis=fsdp_axis, fsdp_size=fsdp_size,
                           ctr_heads=decode)
    return pshape, pspec


def _opt_state_struct(opt, pshape, pspec, state_dtype):
    """eval_shape of opt.init over params + matching shardings."""
    sshape = jax.eval_shape(opt.init, pshape)

    def respec(leaf):
        # scalar state entries replicate; tensors mirror the param spec.
        return leaf
    # delta/nu mirror the params tree when non-scalar.
    def spec_like(sub):
        if hasattr(sub, "shape") and sub.shape == ():
            return P()
        return None
    # Build spec tree with same structure as sshape.
    def build(shape_leaf, path_spec):
        return path_spec
    # delta & nu either mirror params or are scalars (fedavg variants).
    delta_spec = (pspec if jtu.tree_structure(sshape.delta)
                  == jtu.tree_structure(pshape) else P())
    nu_spec = (pspec if jtu.tree_structure(sshape.nu)
               == jtu.tree_structure(pshape) else P())
    sspec = ServerOptState(step=P(), delta=delta_spec, nu=nu_spec)
    if state_dtype != "float32":
        dt = jnp.dtype(state_dtype)
        sshape = ServerOptState(
            step=sshape.step,
            delta=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, dt),
                               sshape.delta),
            nu=jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, dt),
                            sshape.nu))
    return sshape, sspec


def build_train_step(cfg: ModelConfig, mesh, run: RunConfig) -> LoweredPieces:
    model = build_model(cfg)
    opt = make_server_optimizer(run.adaptive)
    n_clients = n_clients_of(mesh)
    batch_shape, batch_spec = S.batch_struct(cfg, "train_4k", mesh)
    b = batch_shape["tokens"].shape[0]
    # batch row -> client id (contiguous blocks, matching how the data
    # pipeline shards client batches onto data shards).
    client_ids = jnp.arange(b, dtype=jnp.int32) * n_clients // b

    def train_step(params, opt_state, key, batch):
        k_fade, k_noise = jax.random.split(key)

        def loss_fn(p):
            w, _ = faded_loss_weights(k_fade, run.channel, client_ids,
                                      n_clients)
            return model.loss_fn(p, batch, weights=w)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        g_t = add_interference(k_noise, run.channel, grads)   # Eq. (7)
        new_params, new_state = opt.update(g_t, opt_state, params)
        return new_params, new_state, loss

    pshape, pspec = _param_shardings(cfg, mesh, model, run.fsdp)
    sshape, sspec = _opt_state_struct(opt, pshape, pspec, run.state_dtype)
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
    args = (pshape, sshape, key_s, batch_shape)
    in_sh = (S.named(mesh, pspec), S.named(mesh, sspec),
             NamedSharding(mesh, P()), S.named(mesh, batch_spec))
    out_sh = (S.named(mesh, pspec), S.named(mesh, sspec),
              NamedSharding(mesh, P()))
    return LoweredPieces(train_step, args, in_sh, out_sh)


def build_prefill_step(cfg: ModelConfig, mesh, run: RunConfig) -> LoweredPieces:
    model = build_model(cfg)
    batch_shape, batch_spec = S.batch_struct(cfg, "prefill_32k", mesh)
    b, s = batch_shape["tokens"].shape
    length = S.cache_length(cfg, s)

    def prefill_step(params, batch):
        return model.prefill(params, batch, length=length)

    pshape, pspec = _param_shardings(cfg, mesh, model, run.fsdp)
    args = (pshape, batch_shape)
    in_sh = (S.named(mesh, pspec), S.named(mesh, batch_spec))
    # Output: (logits, cache) — let the compiler choose (UNSPECIFIED).
    return LoweredPieces(prefill_step, args, in_sh, None)


def build_decode_step(cfg: ModelConfig, mesh, run: RunConfig,
                      shape_name: str) -> LoweredPieces:
    model = build_model(cfg)
    sh = S.INPUT_SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    length = S.cache_length(cfg, s) + (cfg.n_meta_tokens or 0)

    cache_shape = jax.eval_shape(
        functools.partial(model.init_cache, b, length))
    msize = mesh.shape["model"]
    cache_spec = S.cache_partition_spec(
        cache_shape, mesh, b, lambda n: n % msize == 0,
        shard_cache_seq=run.shard_cache_seq)

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    pshape, pspec = _param_shardings(cfg, mesh, model, run.fsdp, decode=True)
    dp = S._dp(mesh, b)
    token_s = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_s = jax.ShapeDtypeStruct((), jnp.int32)
    args = (pshape, cache_shape, token_s, pos_s)
    in_sh = (S.named(mesh, pspec), S.named(mesh, cache_spec),
             NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(dp, None, None)),
              S.named(mesh, cache_spec))
    # Donate the cache: the decode step updates it in place (no copy of
    # the multi-GB KV buffer per token).
    return LoweredPieces(decode_step, args, in_sh, out_sh,
                         donate_argnums=(1,))


def build_step(cfg: ModelConfig, mesh, run: RunConfig, shape_name: str
               ) -> LoweredPieces:
    cfg = S.shape_config(cfg, shape_name)
    set_moe_sharding(mesh, data_axes(mesh), "model")
    kind = S.INPUT_SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_step(cfg, mesh, run)
    if kind == "prefill":
        return build_prefill_step(cfg, mesh, run)
    return build_decode_step(cfg, mesh, run, shape_name)
