"""MoE dispatch correctness and routing behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.moe import (MoEConfig, _positions_in_expert, moe_apply,
                              moe_init, moe_reference_dense)


def _cfg(**kw):
    base = dict(d_model=32, n_experts=4, top_k=2, d_ff=64,
                capacity_factor=8.0, aux_loss_weight=0.0)
    base.update(kw)
    return MoEConfig(**base)


def test_positions_in_expert():
    e = jnp.array([1, 0, 1, 1, 0, 2], jnp.int32)
    pos = _positions_in_expert(e, 4)
    np.testing.assert_array_equal(np.asarray(pos), [0, 0, 1, 2, 1, 0])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 300),
       e=st.integers(2, 16))
def test_positions_are_dense_ranks(seed, n, e):
    ids = jax.random.randint(jax.random.key(seed), (n,), 0, e)
    pos = np.asarray(_positions_in_expert(ids, e))
    ids = np.asarray(ids)
    for x in range(e):
        got = sorted(pos[ids == x].tolist())
        assert got == list(range(len(got)))


def test_dispatch_matches_dense_reference():
    """With capacity high enough for zero drops, the scatter/gather
    dispatch must equal the run-every-expert dense oracle."""
    cfg = _cfg()
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 10, cfg.d_model), jnp.float32)
    y, aux = moe_apply(p, cfg, x)
    y_ref = moe_reference_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_shared_expert_added():
    cfg_s = _cfg(n_shared_experts=1)
    p = moe_init(jax.random.key(0), cfg_s, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 6, cfg_s.d_model), jnp.float32)
    y_with, _ = moe_apply(p, cfg_s, x)
    from repro.models.layers import swiglu
    y_shared = swiglu(p["shared"], x)
    cfg_n = _cfg()
    y_wo, _ = moe_apply({k: v for k, v in p.items() if k != "shared"},
                        cfg_n, x)
    np.testing.assert_allclose(np.asarray(y_with),
                               np.asarray(y_wo + y_shared), rtol=2e-4,
                               atol=2e-4)


def test_capacity_drops_tokens():
    """Tiny capacity must zero (drop) overflow tokens, not crash."""
    cfg = _cfg(capacity_factor=0.02)   # capacity == 1ish
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model))
    y, _ = moe_apply(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_aux_loss_prefers_balance():
    """Uniform routing gives aux ~ aux_weight; collapsed routing larger."""
    cfg = _cfg(aux_loss_weight=1.0, top_k=1)
    p = moe_init(jax.random.key(0), cfg, jnp.float32)
    # Force router collapse: all-positive inputs + huge weight column 0.
    k = p["router"]["kernel"]
    p_collapsed = dict(p)
    p_collapsed["router"] = {"kernel": jnp.zeros_like(k).at[:, 0].set(50.0)}
    x = jnp.abs(jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model)))
    _, aux_rand = moe_apply(p, cfg, x)
    _, aux_coll = moe_apply(p_collapsed, cfg, x)
    assert float(aux_coll) > 2.0 * float(aux_rand)
    assert 0.5 < float(aux_rand) < 2.0   # ~1 for near-uniform


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.integers(1, 17),
       e=st.sampled_from([2, 4, 8]), k=st.integers(1, 2))
def test_moe_shapes_and_finiteness(b, s, e, k):
    cfg = _cfg(n_experts=e, top_k=min(k, e))
    p = moe_init(jax.random.key(0), cfg, jnp.bfloat16)
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model), jnp.bfloat16)
    y, aux = moe_apply(p, cfg, x)
    assert y.shape == x.shape and y.dtype == x.dtype
    assert bool(jnp.isfinite(aux))
