"""Synthetic datasets (the container is offline — no CIFAR/EMNIST
downloads). Each generator keeps the statistical knobs the paper varies:
class structure for the classification tasks, and a power-law token
distribution for the LM tasks.
"""

from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass
class ClassificationData:
    x: np.ndarray          # (N, d) float32 or (N, H, W, C) images
    y: np.ndarray          # (N,) int64
    n_classes: int


def gaussian_mixture(n: int, d: int, n_classes: int, seed: int = 0,
                     sep: float = 2.0, noise: float = 1.0
                     ) -> ClassificationData:
    """EMNIST-like stand-in: one Gaussian blob per class (separation
    ``sep``), the regime where logistic regression is the right model —
    matching the paper's convex EMNIST experiment."""
    rng = np.random.default_rng(seed)
    means = rng.normal(0, sep, (n_classes, d)).astype(np.float32)
    y = rng.integers(0, n_classes, n).astype(np.int64)
    x = means[y] + noise * rng.normal(0, 1, (n, d)).astype(np.float32)
    return ClassificationData(x, y, n_classes)


def synthetic_images(n: int, size: int = 16, channels: int = 3,
                     n_classes: int = 10, seed: int = 0
                     ) -> ClassificationData:
    """CIFAR-like stand-in: class-specific low-frequency templates +
    pixel noise; requires conv features to separate well (exercises the
    ResNet-tiny model the way CIFAR exercises ResNet-18)."""
    rng = np.random.default_rng(seed)
    # Low-frequency class templates via random 4x4 patterns upsampled.
    small = rng.normal(0, 1, (n_classes, 4, 4, channels)).astype(np.float32)
    templates = np.repeat(np.repeat(small, size // 4, 1), size // 4, 2)
    y = rng.integers(0, n_classes, n).astype(np.int64)
    x = templates[y] + 0.8 * rng.normal(0, 1, (n, size, size, channels)
                                        ).astype(np.float32)
    return ClassificationData(x.astype(np.float32), y, n_classes)


def token_stream(n_tokens: int, vocab: int, seed: int = 0,
                 order: int = 2) -> np.ndarray:
    """Synthetic LM corpus: Zipfian unigram mixed with a deterministic
    bigram rule so there is actual structure to learn (loss falls below
    the unigram entropy when the model works)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks
    p /= p.sum()
    toks = rng.choice(vocab, size=n_tokens, p=p).astype(np.int32)
    # Deterministic structure: with prob 1/2, next token = (prev * 7 + 3) % vocab.
    mask = rng.random(n_tokens) < 0.5
    for i in range(1, n_tokens):
        if mask[i]:
            toks[i] = (toks[i - 1] * 7 + 3) % vocab
    return toks


def lm_batches(tokens: np.ndarray, batch: int, seq: int, n_batches: int,
               seed: int = 0) -> np.ndarray:
    """Sample (n_batches, batch, seq) windows from a token stream."""
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, len(tokens) - seq - 1, (n_batches, batch))
    return np.stack([[tokens[s:s + seq] for s in row] for row in starts])
