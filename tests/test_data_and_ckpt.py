"""Data partitioning, pipeline, and checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

import repro.checkpoint as ckpt
from repro.data import (FederatedBatcher, dirichlet_partition, gaussian_mixture,
                        heterogeneity_index, iid_partition, token_stream)


@settings(max_examples=10, deadline=None)
@given(n_clients=st.integers(2, 20), seed=st.integers(0, 1000))
def test_partition_is_exact_cover(n_clients, seed):
    labels = np.random.default_rng(seed).integers(0, 10, 2000)
    parts = dirichlet_partition(labels, n_clients, 0.5, seed)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(2000))


def test_dirichlet_heterogeneity_ordering():
    labels = np.random.default_rng(0).integers(0, 10, 20_000)
    h_verynoniid = heterogeneity_index(
        dirichlet_partition(labels, 20, 0.05, 0), labels)
    h_mild = heterogeneity_index(
        dirichlet_partition(labels, 20, 1.0, 0), labels)
    h_iid = heterogeneity_index(iid_partition(len(labels), 20, 0), labels)
    assert h_verynoniid > h_mild > h_iid


def test_batcher_shapes():
    data = gaussian_mixture(1000, 8, 4)
    fb = FederatedBatcher(data, 10, 16, dir_alpha=0.2)
    b = fb(0)
    assert b["x"].shape == (10, 16, 8)
    assert b["y"].shape == (10, 16)
    fb3 = FederatedBatcher(data, 5, 4, dir_alpha=0.5, local_steps=3)
    b3 = fb3(0)
    assert b3["x"].shape == (5, 3, 4, 8)


def test_token_stream_has_structure():
    toks = token_stream(50_000, vocab=97, seed=0)
    follow = ((toks[1:] == (toks[:-1] * 7 + 3) % 97).mean())
    assert follow > 0.4   # learnable bigram rule present


def test_checkpoint_roundtrip_with_server_state(tmp_path):
    from repro.core import AdaptiveConfig, init_server
    params = {"w": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones(4, jnp.bfloat16)}}
    state = init_server(params, AdaptiveConfig())
    tree = {"params": params, "state": state,
            "round": jnp.asarray(17), "key": jax.random.key_data(jax.random.key(5))}
    path = os.path.join(tmp_path, "round_17.npz")
    ckpt.save(path, tree)
    restored = ckpt.load(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_round(tmp_path):
    for r in (3, 11, 7):
        ckpt.save(os.path.join(tmp_path, f"round_{r}.npz"), {"x": jnp.ones(1)})
    latest = ckpt.latest_round(str(tmp_path))
    assert latest.endswith("round_11.npz")
    assert ckpt.latest_round(str(tmp_path) + "/nonexistent") is None
