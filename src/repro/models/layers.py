"""Shared neural-net primitives (pure JAX, functional params-as-pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init fns take an explicit key;
  * compute dtype defaults to bf16, params stored in ``param_dtype``;
  * all matmuls go through ``dense`` so dtype promotion is uniform.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    """He/depth-scaled truncated normal initialiser."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def dense_init(key, in_dim: int, out_shape: Sequence[int], dtype=jnp.bfloat16,
               use_bias: bool = False, scale: float = 1.0) -> dict:
    shape = (in_dim, *out_shape)
    p = {"kernel": truncated_normal_init(key, shape, scale, dtype)}
    if use_bias:
        p["bias"] = jnp.zeros(tuple(out_shape), dtype)
    return p


def dense(p: dict, x: jax.Array, compute_dtype=None) -> jax.Array:
    """x: (..., in_dim) @ kernel (in_dim, *out) -> (..., *out).
    Compute dtype follows the kernel's storage dtype unless overridden."""
    compute_dtype = compute_dtype or p["kernel"].dtype
    k = p["kernel"].astype(compute_dtype)
    y = jax.lax.dot_general(x.astype(compute_dtype), k,
                            (((x.ndim - 1,), (0,)), ((), ())))
    if "bias" in p:
        y = y + p["bias"].astype(compute_dtype)
    return y


def rmsnorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.bfloat16) -> dict:
    return {"table": (jax.random.normal(key, (vocab, dim), jnp.float32)
                      * (1.0 / math.sqrt(dim))).astype(dtype)}


def embed(p: dict, ids: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0).astype(compute_dtype)


def sinusoidal_embed(positions: jax.Array, dim: int,
                     max_timescale: float = 10000.0) -> jax.Array:
    """Transformer sin/cos position embeddings. positions: (...,) int."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_timescale)
                    * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# --------------------------------------------------------------------------
# Rotary position embeddings.
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """Rotate pairs. x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs.
# --------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, (d_ff,), dtype),
        "up": dense_init(k2, d_model, (d_ff,), dtype),
        "down": dense_init(k3, d_ff, (d_model,), dtype),
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(dense(p["gate"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], g * dense(p["up"], x))


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16,
                  use_bias: bool = True) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "up": dense_init(k1, d_model, (d_ff,), dtype, use_bias=use_bias),
        "down": dense_init(k2, d_ff, (d_model,), dtype, use_bias=use_bias),
    }


def gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(dense(p["up"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["down"], h)


# --------------------------------------------------------------------------
# Losses.
# --------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 weights: Optional[jax.Array] = None,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Weighted-mean token cross-entropy.

    logits: (..., V) float; labels: (...) int32;
    weights: per-*example* weights broadcastable to labels' shape (used by
    the OTA faded-loss formulation); mask: 0/1 validity per token.

    Normalisation uses the *unweighted* token count so that with fading
    weights h the result is exactly mean_i h_i * nll_i (the faded OTA
    average of Eq. 7), not a self-normalised ratio.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    m = jnp.ones_like(nll) if mask is None else mask.astype(jnp.float32)
    wn = nll * m
    if weights is not None:
        wn = wn * jnp.broadcast_to(
            weights.reshape(weights.shape + (1,) * (nll.ndim - weights.ndim)),
            nll.shape).astype(jnp.float32)
    return jnp.sum(wn) / jnp.maximum(jnp.sum(m), 1.0)
