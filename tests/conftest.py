# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the real single CPU device; only launch/dryrun.py forces 512 devices.
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
