"""Pure-jnp oracles for every Pallas kernel (the ``ref`` side of the
kernel allclose tests, and the fallback path on non-TPU backends)."""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def adaptive_update_ref(g: jax.Array, delta: jax.Array, nu: jax.Array,
                        w: jax.Array, *, lr: float, beta1: float,
                        beta2: float, alpha: float, eps: float,
                        mode: str) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One ADOTA server update on a flat parameter slab (paper Eq. 8-11).

    mode: "adagrad" -> v += |Delta|^a ; "adam" -> v = b2 v + (1-b2)|Delta|^a.
    All state in f32; w keeps its dtype.
    """
    gf = g.astype(jnp.float32)
    delta = beta1 * delta + (1.0 - beta1) * gf
    da = jnp.abs(delta) ** alpha
    if mode == "adagrad":
        nu = nu + da
    elif mode == "adam":
        nu = beta2 * nu + (1.0 - beta2) * da
    else:
        raise ValueError(mode)
    denom = (nu + eps) ** (1.0 / alpha)
    w_new = (w.astype(jnp.float32) - lr * delta / denom).astype(w.dtype)
    return delta, nu, w_new


def ota_channel_ref(grads: jax.Array, h: jax.Array, u: jax.Array,
                    e: jax.Array, *, alpha: float, scale: float
                    ) -> jax.Array:
    """Fused OTA MAC on a slab: (1/N) sum_n h_n grads[n] + xi, where xi is
    the CMS transform of uniform angles u in (-pi/2, pi/2) and Exp(1)
    draws e (both shape (d,)).

    grads: (N, d); h: (N,). Returns (d,) float32.
    """
    n = grads.shape[0]
    agg = jnp.einsum("n,nd->d", h.astype(jnp.float32),
                     grads.astype(jnp.float32)) / n
    a = alpha
    xi = (jnp.sin(a * u) / jnp.cos(u) ** (1.0 / a)
          * (jnp.cos((1.0 - a) * u) / jnp.maximum(e, 1e-7))
          ** ((1.0 - a) / a))
    return agg + scale * xi


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Masked GQA attention oracle. q: (B,Sq,H,D); k,v: (B,Sk,K,D)."""
    b, sq, hn, d = q.shape
    kheads = k.shape[2]
    g = hn // kheads
    qg = q.reshape(b, sq, kheads, g, d).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    dpos = qpos[:, None] - kpos[None, :]
    ok = jnp.ones_like(dpos, bool)
    if causal:
        ok &= dpos >= 0
    if window is not None:
        ok &= dpos < window
    scores = jnp.where(ok[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hn, d).astype(q.dtype)
