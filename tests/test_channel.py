"""OTA channel statistics: alpha-stable sampler, fading, Upsilon."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ota_aggregate_stacked
from repro.core.channel import (OTAChannelConfig, UplinkConfig,
                                sample_alpha_stable, sample_fading,
                                sample_interference, upsilon)
from repro.core.tail_index import log_moment_estimate

N = 200_000


def test_alpha2_is_gaussian():
    x = sample_alpha_stable(jax.random.key(1), 2.0, (N,))
    # S(2, 0, c) == N(0, 2 c^2): var ~ 2.
    assert abs(float(jnp.var(x)) - 2.0) < 0.05
    # Gaussian kurtosis.
    k = float(jnp.mean(x**4) / jnp.var(x) ** 2)
    assert abs(k - 3.0) < 0.2


@pytest.mark.parametrize("alpha", [1.2, 1.5, 1.8, 2.0])
def test_tail_index_recovered(alpha):
    x = sample_alpha_stable(jax.random.key(2), alpha, (N,))
    a_hat, c_hat = log_moment_estimate(x)
    assert abs(float(a_hat) - alpha) < 0.05
    assert abs(float(c_hat) - 1.0) < 0.05


def test_scale_recovered():
    x = sample_alpha_stable(jax.random.key(3), 1.5, (N,), scale=0.1)
    _, c_hat = log_moment_estimate(x)
    assert abs(float(c_hat) - 0.1) < 0.02


def test_heavy_tails_have_extremes():
    """Smaller alpha -> heavier tails -> larger extreme draws (Remark 6)."""
    x12 = sample_alpha_stable(jax.random.key(4), 1.2, (N,))
    x20 = sample_alpha_stable(jax.random.key(4), 2.0, (N,))
    assert float(jnp.max(jnp.abs(x12))) > 10 * float(jnp.max(jnp.abs(x20)))


def test_rayleigh_fading_moments():
    cfg = OTAChannelConfig(fading="rayleigh", mu_c=1.0)
    h = sample_fading(jax.random.key(5), cfg, (N,))
    assert abs(float(h.mean()) - 1.0) < 0.01
    assert abs(float(h.var()) - cfg.fading_var) < 0.01
    assert float(h.min()) >= 0.0


def test_no_fading_no_interference():
    cfg = OTAChannelConfig(fading="none", interference=False)
    h = sample_fading(jax.random.key(6), cfg, (100,))
    xi = sample_interference(jax.random.key(7), cfg, (100,))
    np.testing.assert_array_equal(np.asarray(h), 1.0)
    np.testing.assert_array_equal(np.asarray(xi), 0.0)


@settings(max_examples=30, deadline=None)
@given(n1=st.integers(2, 100), n2=st.integers(2, 100),
       d=st.integers(1, 10_000))
def test_upsilon_monotone_in_clients(n1, n2, d):
    """Remark 12: more clients -> smaller Upsilon (faster convergence)."""
    cfg = OTAChannelConfig(alpha=1.5)
    u1 = upsilon(cfg, d, min(n1, n2), grad_bound=1.0)
    u2 = upsilon(cfg, d, max(n1, n2), grad_bound=1.0)
    assert u2 <= u1 + 1e-9


def test_upsilon_monotone_in_fading_variance():
    """Remark 11: larger sigma_c -> larger Upsilon."""
    lo = OTAChannelConfig(fading="gaussian", sigma_c=0.1)
    hi = OTAChannelConfig(fading="gaussian", sigma_c=0.9)
    assert upsilon(hi, 1000, 50, 1.0) > upsilon(lo, 1000, 50, 1.0)


def test_alpha_must_be_valid():
    with pytest.raises(ValueError):
        OTAChannelConfig(alpha=0.9)
    with pytest.raises(ValueError):
        OTAChannelConfig(alpha=2.5)
    with pytest.raises(ValueError):
        OTAChannelConfig(fading="nakagami")


@pytest.mark.parametrize("fading,threshold", [("rayleigh", 0.2),
                                              ("rayleigh", 0.6),
                                              ("gaussian", 0.5),
                                              ("none", 0.2)])
def test_power_control_moments_match_empirical(fading, threshold):
    """Satellite bugfix: with power_control=True the effective h is
    Bernoulli(p), p = P(h >= pc_threshold) — fading_mean/fading_var must
    report p and p(1-p) (they used to report the raw Rayleigh moments,
    ignoring truncated inversion entirely)."""
    cfg = OTAChannelConfig(fading=fading, power_control=True,
                           pc_threshold=threshold)
    h = np.asarray(sample_fading(jax.random.key(13), cfg, (400_000,)))
    p = cfg.pc_transmit_prob
    assert cfg.fading_mean == pytest.approx(p)
    assert cfg.fading_var == pytest.approx(p * (1.0 - p))
    assert abs(h.mean() - cfg.fading_mean) < 5e-3
    assert abs(h.var() - cfg.fading_var) < 5e-3
    # E[h^2] == p exactly for a 0/1 variable — the moment Upsilon uses
    assert cfg.fading_mean**2 + cfg.fading_var == pytest.approx(p)


def test_power_control_upsilon_uses_effective_moments():
    """Upsilon's fading term must shrink when power control replaces a
    high-variance channel with near-sure 0/1 transmission (and not be
    computed from the raw Rayleigh moments)."""
    raw = OTAChannelConfig(fading="rayleigh", interference=False)
    pc = OTAChannelConfig(fading="rayleigh", power_control=True,
                          pc_threshold=0.2, interference=False)
    p = pc.pc_transmit_prob
    # E[h^2]: raw Rayleigh has mu^2(1 + (4/pi - 1)) > p
    assert raw.fading_mean**2 + raw.fading_var > p
    assert upsilon(pc, 1000, 50, 1.0) < upsilon(raw, 1000, 50, 1.0)


def test_power_control_truncated_inversion():
    """With CSI power control, effective fading is 0/1 (silent in deep
    fades, perfectly inverted otherwise) and most clients transmit."""
    cfg = OTAChannelConfig(fading="rayleigh", power_control=True,
                           pc_threshold=0.2)
    h = sample_fading(jax.random.key(0), cfg, (50_000,))
    vals = np.unique(np.asarray(h))
    assert set(vals.tolist()) <= {0.0, 1.0}
    # Rayleigh(mean 1): P[h < 0.2] ~ 3%; most clients transmit.
    assert 0.9 < float(h.mean()) <= 1.0


@pytest.mark.parametrize("uplink", ["f32", "int8"])
def test_power_control_parity_jnp_vs_pallas(uplink):
    """Truncated channel inversion flows identically through the slab
    pipeline: the pallas backend (and the quantized uplink) must see the
    exact 0/1 effective fading the jnp path sees, on both uplinks.
    Use enough clients that a deep fade (h == 0) actually occurs."""
    n = 64
    grads = {f"p{i}": jax.random.normal(jax.random.key(60 + i), (n,) + s)
             for i, s in enumerate([(7, 19), (257,), (1,)])}
    cfg = OTAChannelConfig(fading="rayleigh", power_control=True,
                           pc_threshold=0.6, alpha=1.5, xi_scale=0.1,
                           uplink=UplinkConfig(mode=uplink))
    key = jax.random.key(11)
    g_ref, h_ref = ota_aggregate_stacked(key, cfg, grads)
    g_slab, h_slab = ota_aggregate_stacked(
        key, dataclasses.replace(cfg, backend="pallas"), grads)
    np.testing.assert_array_equal(np.asarray(h_ref), np.asarray(h_slab))
    assert set(np.unique(np.asarray(h_ref)).tolist()) == {0.0, 1.0}
    tol = 1e-5 if uplink == "f32" else 5e-3   # int8: one quantum/entry
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_slab)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)
