"""Quickstart: 60 rounds of ADOTA-FL (Adam-OTA) on a synthetic federated
classification task, next to the FedAvgM baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step, run_rounds)
from repro.data import FederatedBatcher, gaussian_mixture
from repro.models.vision import accuracy, logistic_regression


def train(optimizer: str, lr: float) -> float:
    n_clients = 20
    data = gaussian_mixture(4000, 32, 10, seed=0)
    model = logistic_regression(32, 10)
    batcher = FederatedBatcher(data, n_clients, 16, dir_alpha=0.1)

    channel = OTAChannelConfig(alpha=1.5, xi_scale=0.5)   # strong interference
    server = AdaptiveConfig(optimizer=optimizer, lr=lr, alpha=1.5, beta2=0.3)
    round_step = make_round_step(model.loss_fn, channel, server,
                                 FLConfig(n_clients=n_clients))
    params = model.init(jax.random.key(0))
    state = init_server(params, server)

    def batch_fn(t, key):
        b = batcher(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    params, state, hist = run_rounds(round_step, params, state,
                                     jax.random.key(1), batch_fn,
                                     n_rounds=60, log_every=20)
    acc = accuracy(model, params, jnp.asarray(data.x), data.y)
    print(f"{optimizer:12s} final loss {hist[-1]['loss']:.4f}  acc {acc:.4f}")
    return acc


if __name__ == "__main__":
    print("== Adam-OTA (paper algorithm) ==")
    acc_adam = train("adam_ota", lr=0.05)
    print("== FedAvgM-OTA (paper baseline) ==")
    acc_avgm = train("fedavgm", lr=0.01)
    print(f"\nADOTA improvement: +{(acc_adam - acc_avgm) * 100:.1f} pts accuracy "
          "under alpha=1.5 interference")
