"""End-to-end behaviour: the full ADOTA-FL stack (data partition ->
clients -> OTA channel -> adaptive server) on the paper's model kinds,
plus the LM round step the production framework runs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step, run_rounds)
from repro.data import FederatedBatcher, synthetic_images, token_stream
from repro.models.vision import accuracy, resnet_tiny


def test_resnet_tiny_federated_training():
    """The paper's CIFAR/ResNet experiment shape, CPU-sized: conv model,
    non-iid Dirichlet split, Rayleigh + alpha-stable channel, Adam-OTA."""
    data = synthetic_images(1500, size=16, channels=3, n_classes=4, seed=0)
    model = resnet_tiny(4, channels=(8, 16), blocks_per_stage=1)
    n_clients = 10
    fb = FederatedBatcher(data, n_clients, 8, dir_alpha=0.5)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.02)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.1, alpha=1.5, beta2=0.3)
    rs = make_round_step(model.loss_fn, ch, ad, FLConfig(n_clients=n_clients))
    params = model.init(jax.random.key(0))
    state = init_server(params, ad)

    def batch_fn(t, key):
        b = fb(t)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    params, state, hist = run_rounds(rs, params, state, jax.random.key(1),
                                     batch_fn, 40)
    assert hist[-1]["loss"] < hist[0]["loss"]
    acc = accuracy(model, params, jnp.asarray(data.x[:500]), data.y[:500])
    assert acc > 0.5   # 4 classes, chance = 0.25


def test_lm_federated_round_step():
    """A reduced qwen-style LM through the same FL machinery — the shape
    of the production multi-pod training loop."""
    from repro.configs import smoke_config
    from repro.models.model import build_model

    cfg = dataclasses.replace(smoke_config("qwen3-14b"), vocab=97,
                              n_layers=2)
    model = build_model(cfg)
    toks = token_stream(30_000, vocab=97, seed=0)
    n_clients, b, s = 4, 2, 32

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    ch = OTAChannelConfig(alpha=1.7, xi_scale=0.02)
    ad = AdaptiveConfig(optimizer="adagrad_ota", lr=0.05, alpha=1.7)
    rs = make_round_step(loss_fn, ch, ad, FLConfig(n_clients=n_clients))
    params = model.init(jax.random.key(0))
    state = init_server(params, ad)
    rng = np.random.default_rng(0)

    def batch_fn(t, key):
        starts = rng.integers(0, len(toks) - s - 1, (n_clients, b))
        arr = np.stack([[toks[i:i + s] for i in row] for row in starts])
        return {"tokens": jnp.asarray(arr)}

    params, state, hist = run_rounds(rs, params, state, jax.random.key(1),
                                     batch_fn, 30)
    # Loss must drop substantially from the ~ln(97)=4.57 start toward the
    # deterministic bigram structure in the stream.
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_checkpoint_resume_preserves_state():
    """Server state round-trips exactly through a checkpoint mid-run."""
    import os
    import tempfile

    import repro.checkpoint as ckpt
    from repro.data import gaussian_mixture
    from repro.models.vision import logistic_regression

    data = gaussian_mixture(500, 8, 3, seed=2)
    model = logistic_regression(8, 3)
    ch = OTAChannelConfig(alpha=1.6, xi_scale=0.1)
    ad = AdaptiveConfig(optimizer="adam_ota", lr=0.05, alpha=1.6)
    rs = make_round_step(model.loss_fn, ch, ad, FLConfig(n_clients=5))
    fb = FederatedBatcher(data, 5, 8, dir_alpha=0.5)
    batch = {"x": jnp.asarray(fb(0)["x"]), "y": jnp.asarray(fb(0)["y"])}

    params = model.init(jax.random.key(0))
    state = init_server(params, ad)
    for t in range(3):
        params, state, _ = rs(params, state, jax.random.fold_in(
            jax.random.key(3), t), batch)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "round_3.npz")
        ckpt.save(path, {"params": params, "state": state})
        restored = ckpt.load(path, {"params": params, "state": state})
    # bitwise identical state -> identical continuation
    pA, sA = params, state
    pB, sB = restored["params"], restored["state"]
    for t in range(3, 6):
        k = jax.random.fold_in(jax.random.key(3), t)
        pA, sA, _ = rs(pA, sA, k, batch)
        pB, sB, _ = rs(pB, sB, k, batch)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
