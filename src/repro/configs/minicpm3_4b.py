"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: 62L, d_model 2560, 40 heads,
Multi-head Latent Attention (q_lora 768, kv_lora 256, nope 64 + rope 32),
d_ff 6400, vocab 73448. Decode caches only the 288-dim latent per token."""

from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    arch="minicpm3-4b", family="mla",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, q_lora_rank=768, kv_lora_rank=256,
    qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
    notes="MLA [hf:openbmb/MiniCPM3-4B]",
)
