"""Cross-backend parity check of the sharded slab engine (CLI).

Runs full ADOTA rounds on the jnp reference backend, the single-device
pallas slab engine, and the mesh-distributed ``pallas_sharded`` engine
on one or more client-mesh shapes, then reports the maximum deviation of
params / optimizer state / metrics. Also asserts seeded determinism:
the sharded round run twice with the same key must be bitwise equal.

This is the executable form of the sharded-engine acceptance contract
(all three backends consume identical PRNG draws and differ only by f32
summation order); tests/test_shard_roundstep.py runs it as a subprocess
so the main pytest process keeps its real single-device view.

    PYTHONPATH=src python -m repro.launch.shard_check \
        --meshes 2 4,2 --optimizers adam_ota fedavgm --tol 1e-5

The XLA flag below MUST precede any jax import (jax locks the device
count at first backend init); at least 8 host devices are forced, or
the largest --meshes product if bigger (read from raw argv — argparse
would come too late).
"""

import sys

from repro.launch.hostdev import (force_host_devices, mesh_device_count,
                                  positive_int)

force_host_devices(mesh_device_count(sys.argv, "--meshes"))

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (AdaptiveConfig, FLConfig, OTAChannelConfig,
                        init_server, make_round_step)
from repro.launch.mesh import make_client_mesh


def _max_dev(a, b) -> float:
    assert jax.tree.structure(a) == jax.tree.structure(b), (
        jax.tree.structure(a), jax.tree.structure(b))
    dev = 0.0
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        denom = np.maximum(np.abs(x), 1.0)
        dev = max(dev, float(np.max(np.abs(x - y) / denom)))
    return dev


def _run(backend: str, mesh, params, batches, ch, ad, fl, rounds: int):
    rs = make_round_step(_loss_fn, ch, ad, fl, backend=backend, mesh=mesh)
    p, s = params, init_server(params, ad)
    for t in range(rounds):
        p, s, m = rs(p, s, jax.random.fold_in(jax.random.key(7), t), batches)
    return p, s, m


def _loss_fn(p, batch):
    return sum(jnp.mean((x - t) ** 2)
               for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(batch)))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", nargs="+", default=["2", "4,2"],
                    help="client-mesh shapes, e.g. --meshes 2 4,2")
    ap.add_argument("--optimizers", nargs="+",
                    default=["adam_ota", "fedavgm"])
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=positive_int, default=2)
    ap.add_argument("--tol", type=float, default=1e-5)
    args = ap.parse_args(argv)

    params = {
        "emb": jax.random.normal(jax.random.key(0), (7, 33)),
        "w": jax.random.normal(jax.random.key(1), (257,)),
        "b": jax.random.normal(jax.random.key(2), (1,)),
    }
    batches = jax.tree.map(
        lambda p: jax.random.normal(jax.random.key(3),
                                    (args.clients,) + p.shape), params)
    ch = OTAChannelConfig(alpha=1.5, xi_scale=0.1)
    fl = FLConfig(n_clients=args.clients)

    failures = 0
    for opt in args.optimizers:
        ad = AdaptiveConfig(optimizer=opt, lr=0.05, alpha=1.5, beta2=0.3)
        p_ref, s_ref, m_ref = _run("jnp", None, params, batches, ch, ad, fl,
                                   args.rounds)
        p_slab, _, _ = _run("pallas", None, params, batches, ch, ad, fl,
                            args.rounds)
        dev = _max_dev(p_ref, p_slab)
        print(f"{opt:12s} pallas            dev={dev:.2e}")
        failures += dev > args.tol
        for mesh_str in args.meshes:
            shape = tuple(int(x) for x in mesh_str.split(","))
            mesh = make_client_mesh(shape)
            p_s, s_s, m_s = _run("pallas_sharded", mesh, params, batches, ch,
                                 ad, fl, args.rounds)
            devs = {
                "params": _max_dev(p_ref, p_s),
                "delta": _max_dev(s_ref.delta, s_s.delta),
                "nu": _max_dev(s_ref.nu, s_s.nu),
                "loss": abs(float(m_ref.loss) - float(m_s.loss)),
                "|g_t|": abs(float(m_ref.noisy_grad_norm)
                             - float(m_s.noisy_grad_norm))
                / max(abs(float(m_ref.noisy_grad_norm)), 1.0),
            }
            worst = max(devs.values())
            ok = worst <= args.tol
            failures += not ok
            print(f"{opt:12s} sharded mesh={mesh_str:5s} "
                  + " ".join(f"{k}={v:.2e}" for k, v in devs.items())
                  + ("  OK" if ok else "  FAIL"))
            # Seeded determinism: the identical run must be bitwise equal.
            p_s2, s_s2, m_s2 = _run("pallas_sharded", mesh, params, batches,
                                    ch, ad, fl, args.rounds)
            for x, y in zip(jax.tree.leaves((p_s, s_s)),
                            jax.tree.leaves((p_s2, s_s2))):
                if not np.array_equal(np.asarray(x), np.asarray(y)):
                    print(f"{opt:12s} sharded mesh={mesh_str}: "
                          "NONDETERMINISTIC rerun")
                    failures += 1
                    break

    print("PARITY OK" if failures == 0 else f"PARITY FAIL ({failures})")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
