"""jit'd public wrappers around the Pallas kernels.

``fused_server_update`` is the production entry point: it applies the
fused ADOTA update kernel leaf-by-leaf over the parameter pytree (each
leaf flattened to a slab), replacing the ~10-pass jnp expression chain
of ``repro.core.adaptive`` with one read-modify-write HBM pass. The jnp
reference implementations remain the default on non-TPU backends; the
kernels run in interpret mode there (tests) and compiled on TPU.
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.adaptive import ServerOptState
from repro.kernels.adaptive_update import adaptive_update_slab
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ota_channel import ota_channel_slab

PyTree = Any


@functools.partial(jax.jit, static_argnames=("lr", "beta1", "beta2", "alpha",
                                             "eps", "mode", "interpret"))
def fused_server_update(g: PyTree, state: ServerOptState, params: PyTree, *,
                        lr: float, beta1: float, beta2: float, alpha: float,
                        eps: float, mode: str = "adam",
                        interpret: bool = True
                        ) -> Tuple[PyTree, ServerOptState]:
    """Kernel-fused equivalent of adagrad_ota/adam_ota .update()."""

    def leaf(gl, dl, vl, wl):
        shape = wl.shape
        dn, vn, wn = adaptive_update_slab(
            gl.reshape(-1), dl.reshape(-1), vl.reshape(-1), wl.reshape(-1),
            lr=lr, beta1=beta1, beta2=beta2, alpha=alpha, eps=eps,
            mode=mode, interpret=interpret)
        return dn.reshape(shape), vn.reshape(shape), wn.reshape(shape)

    flat_g, treedef = jax.tree.flatten(g)
    flat_d = treedef.flatten_up_to(state.delta)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_w = treedef.flatten_up_to(params)
    outs = [leaf(*t) for t in zip(flat_g, flat_d, flat_v, flat_w)]
    delta = jax.tree.unflatten(treedef, [o[0] for o in outs])
    nu = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_w = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_w, ServerOptState(state.step + 1, delta, nu)


@functools.partial(jax.jit, static_argnames=("alpha", "scale", "interpret"))
def fused_ota_aggregate(grads: jax.Array, h: jax.Array, key: jax.Array, *,
                        alpha: float, scale: float,
                        interpret: bool = True) -> jax.Array:
    """Kernel-fused OTA MAC on stacked client gradients (N, d)."""
    import math
    d = grads.shape[1]
    ku, ke = jax.random.split(key)
    u = jax.random.uniform(ku, (d,), jnp.float32,
                           -math.pi / 2 + 1e-6, math.pi / 2 - 1e-6)
    e = -jnp.log(jax.random.uniform(ke, (d,), jnp.float32,
                                    minval=jnp.finfo(jnp.float32).tiny))
    return ota_channel_slab(grads, h, u, e, alpha=alpha, scale=scale,
                            interpret=interpret)


causal_flash_attention = jax.jit(
    functools.partial(flash_attention, causal=True),
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
